//! The job engine: one long-lived owner of backends, budget, and data
//! that executes [`JobSpec`]s concurrently and streams typed [`Event`]s.
//!
//! [`Engine::submit`] assigns a job id, emits `queued`, and hands the job
//! to a worker thread gated by the engine's slot budget (`job_slots`
//! concurrent jobs; each job's native kernels get the
//! [`ThreadBudget`]-planned core share so `job_slots x kernel_threads <=
//! cores`, the PR 4 planner applied one level up). The caller gets a
//! [`JobHandle`]: an event receiver plus a [`CancelToken`] that stops the
//! job cooperatively at its next epoch / eval-batch / fleet-run boundary.
//!
//! What the engine owns **once**, across jobs:
//! * the dataset cache — `(kind, sizes) -> (train, test)` built through
//!   [`crate::experiments::make_data`], so concurrent jobs share data;
//! * the resolved native backend cores
//!   ([`crate::runtime::NativeShared`]) — a variant is resolved once per
//!   engine and every job's workers are `Arc` clones (PJRT clients are
//!   process-pinned and not `Send`, so PJRT jobs compile on their own job
//!   thread — the factory seam hides the difference);
//! * the PJRT availability probe, so `backend=auto` resolves identically
//!   for every job.
//!
//! Determinism: the engine adds no RNG and the observers are passive, so
//! a job's result is bit-identical to calling the coordinator directly
//! with the same config — `tests/serve_api.rs` pins this.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::api::event::{validate_result, Event, JobId, JobResult};
use crate::api::job::{
    BenchJob, EvalJob, FleetBenchJob, FleetJob, FleetShardJob, HealthJob, InfoJob, JobSpec,
    LoadJob, MetricsJob, PredictJob, PredictOneJob, SaveJob, ServeBenchJob, StudyJob, TrainJob,
};
use crate::api::registry::{Registry, WarmModel};
use crate::coordinator::observer::{retry_after_ms, Cancelled, Observer};
use crate::coordinator::remote::{
    dataset_fingerprint, run_fleet_remote, run_study_remote, RemoteError, RemoteJob, WorkerPool,
};
use crate::coordinator::trainer::EpochLog;
use crate::coordinator::{
    evaluate_observed, fleet_budget, is_cancelled, is_overloaded, run_fleet, run_fleet_parallel,
    run_fleet_parallel_seeded, run_study, train_run, warmup,
};
use crate::data::Dataset;
use crate::experiments::{make_data, DataKind, Scale};
use crate::runtime::checkpoint;
use crate::runtime::native::available_cores;
use crate::runtime::{
    Backend, BackendFactory, BackendKind, EngineSpec, EvalPrecision, Manifest, ModelState,
    NativeShared, PjrtStatus, ThreadBudget,
};
use crate::serve::batcher::{Batcher, BatcherConfig};
use crate::serve::metrics::ServeMetrics;
use crate::util::json::Json;

/// Engine construction knobs.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Default dataset sizes / run counts for jobs that don't override
    /// them (`AIRBENCH_TRAIN_N` etc. respected, like the CLI).
    pub scale: Scale,
    /// Where PJRT artifacts are looked up.
    pub artifacts_dir: PathBuf,
    /// Concurrent job slots. `1` (the default) gives each job the whole
    /// machine — the one-shot CLI setting. `0` = auto: one slot per core
    /// with single-threaded kernels, the serve-daemon setting. Values in
    /// between split the cores evenly (`cores / job_slots` kernel threads
    /// per job). Fleet jobs plan their *internal* parallelism against the
    /// full machine, so fleet-heavy serving should keep `job_slots = 1`.
    pub job_slots: usize,
    /// Micro-batching knobs for `predict_one` serving (DESIGN.md §12):
    /// flush size / deadline of the per-model [`Batcher`] and the bound of
    /// its admission queue. `kernel_threads` is overridden by the engine's
    /// own [`ThreadBudget`] share.
    pub batcher: BatcherConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            scale: Scale::from_env(),
            artifacts_dir: Manifest::default_dir(),
            job_slots: 1,
            batcher: BatcherConfig::default(),
        }
    }
}

/// Cooperative cancellation handle (cloneable; see
/// [`JobHandle::cancel_token`]).
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// Request cancellation. The job stops at its next epoch /
    /// eval-batch / fleet-run boundary and terminates with an `error`
    /// event whose message is `"cancelled"`.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    /// Whether cancellation has been requested.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// A submitted job: the receiving end of its event stream plus its
/// cancellation token. Dropping the handle detaches the job (it keeps
/// running; its events are discarded).
pub struct JobHandle {
    id: JobId,
    rx: Receiver<Event>,
    cancel: CancelToken,
    join: Option<std::thread::JoinHandle<()>>,
}

impl JobHandle {
    /// The engine-assigned job id (1-based).
    pub fn id(&self) -> JobId {
        self.id
    }

    /// A cloneable cancellation token for this job.
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Request cooperative cancellation (see [`CancelToken::cancel`]).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// Blocking iterator over the job's events, ending after the terminal
    /// `result` / `error` event.
    pub fn events(&self) -> std::sync::mpsc::Iter<'_, Event> {
        self.rx.iter()
    }

    /// Drain the stream and return the terminal result (an `error` event
    /// becomes an `Err` with its message).
    pub fn wait(mut self) -> Result<JobResult> {
        let mut terminal: Option<Result<JobResult>> = None;
        for ev in self.rx.iter() {
            match ev {
                Event::Result { result, .. } => terminal = Some(Ok(*result)),
                Event::Error { message, .. } => terminal = Some(Err(anyhow!("{message}"))),
                _ => {}
            }
        }
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        terminal.unwrap_or_else(|| Err(anyhow!("job ended without a terminal event")))
    }
}

struct Inner {
    cfg: EngineConfig,
    budget: ThreadBudget,
    pjrt_available: bool,
    next_id: AtomicU64,
    active: Mutex<usize>,
    gate: Condvar,
    data: Mutex<BTreeMap<String, (Dataset, Dataset)>>,
    shared: Mutex<BTreeMap<String, Arc<NativeShared>>>,
    registry: Registry,
    /// Per-warm-model request batchers, created on the first `predict_one`
    /// that hits the model (keyed by `id@content_hash`, so a model
    /// re-loaded under the same id gets a fresh batcher).
    batchers: Mutex<BTreeMap<String, Arc<Batcher>>>,
    /// Serving counters and latency histograms, shared by every batcher
    /// (the `metrics` job's snapshot source).
    metrics: Arc<ServeMetrics>,
}

/// Releases a job slot even when the job panics.
struct SlotGuard<'a>(&'a Inner);

impl Drop for SlotGuard<'_> {
    fn drop(&mut self) {
        let mut active = self.0.active.lock().unwrap();
        *active -= 1;
        self.0.gate.notify_one();
    }
}

/// Observer that forwards coordinator hooks onto the job's event channel
/// and exposes the job's cancel token to the coordinator's polls.
struct ChannelSink {
    job: JobId,
    tx: Sender<Event>,
    cancel: CancelToken,
}

impl ChannelSink {
    fn send(&self, ev: Event) {
        // A dropped receiver means the client went away; the job finishes
        // regardless (results may be written to disk), so ignore failures.
        let _ = self.tx.send(ev);
    }
}

impl Observer for ChannelSink {
    fn on_epoch(&mut self, log: &EpochLog) {
        self.send(Event::Epoch {
            job: self.job,
            epoch: log.epoch,
            train_loss: log.train_loss,
            train_acc: log.train_acc,
            val_acc: log.val_acc,
        });
    }

    fn on_run(&mut self, run: usize, accuracy: f64) {
        self.send(Event::Run {
            job: self.job,
            run,
            accuracy,
        });
    }

    fn on_log(&mut self, line: &str) {
        self.send(Event::Log {
            job: self.job,
            line: line.to_string(),
        });
    }

    fn cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }
}

/// The long-lived job engine (cheaply cloneable; clones share all state).
#[derive(Clone)]
pub struct Engine {
    inner: Arc<Inner>,
}

impl Engine {
    /// Build an engine. Resolves the slot budget against this machine and
    /// probes PJRT availability once so `backend=auto` is stable across
    /// jobs.
    pub fn new(cfg: EngineConfig) -> Engine {
        let cores = available_cores();
        let slots = if cfg.job_slots == 0 { cores } else { cfg.job_slots };
        let budget = ThreadBudget::plan_on(slots, slots, cores);
        let pjrt_available =
            matches!(PjrtStatus::probe(&cfg.artifacts_dir), PjrtStatus::Available);
        Engine {
            inner: Arc::new(Inner {
                cfg,
                budget,
                pjrt_available,
                next_id: AtomicU64::new(0),
                active: Mutex::new(0),
                gate: Condvar::new(),
                data: Mutex::new(BTreeMap::new()),
                shared: Mutex::new(BTreeMap::new()),
                registry: Registry::default(),
                batchers: Mutex::new(BTreeMap::new()),
                metrics: Arc::new(ServeMetrics::new()),
            }),
        }
    }

    /// An engine with default configuration (one job slot).
    pub fn with_defaults() -> Engine {
        Engine::new(EngineConfig::default())
    }

    /// Resolved concurrent job slots.
    pub fn job_slots(&self) -> usize {
        self.inner.budget.runs_parallel
    }

    /// The engine's warm-model registry (shared by every clone): models
    /// parked by `load` jobs, served by `predict` jobs.
    pub fn registry(&self) -> &Registry {
        &self.inner.registry
    }

    /// Submit a job. Infallible by design: every failure — bad variant,
    /// missing checkpoint, cancelled run — arrives as a terminal `error`
    /// event on the returned handle, so clients handle exactly one error
    /// path. The event sequence is `queued -> started -> (epoch | run |
    /// log)* -> result | error` (a job that fails before its backend
    /// resolves skips `started`). Equivalent to [`Engine::submit_from`]
    /// with tenant 0 (the CLI / stdin default).
    pub fn submit(&self, spec: JobSpec) -> JobHandle {
        self.submit_from(0, spec)
    }

    /// [`Engine::submit`] on behalf of a batcher tenant: `predict_one`
    /// requests from this submission are admitted under `tenant` in the
    /// fair FIFO-per-tenant scheduler (DESIGN.md §12). Serve transports
    /// assign tenants per session; every other job kind ignores it.
    ///
    /// `predict_one` and `metrics` jobs bypass the engine's slot gate: the
    /// batcher's bounded admission queue (typed `overloaded` rejection) is
    /// their admission control, and parking a whole job slot per queued
    /// single-image request would let serving starve training jobs.
    pub fn submit_from(&self, tenant: u64, spec: JobSpec) -> JobHandle {
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let (tx, rx) = channel::<Event>();
        let cancel = CancelToken::default();
        let _ = tx.send(Event::Queued { job: id });
        let inner = Arc::clone(&self.inner);
        let token = cancel.clone();
        let spawn_tx = tx.clone();
        let join = std::thread::Builder::new()
            .name(format!("airbench-job-{id}"))
            .spawn(move || {
                let mut sink = ChannelSink {
                    job: id,
                    tx,
                    cancel: token,
                };
                let token = sink.cancel.clone();
                let lightweight = matches!(
                    spec,
                    JobSpec::PredictOne(_) | JobSpec::Metrics(_) | JobSpec::Health(_)
                );
                let out = if lightweight {
                    exec(&inner, id, tenant, spec, &mut sink)
                } else {
                    match inner.acquire_slot(&token) {
                        Err(e) => Err(e),
                        Ok(_guard) => exec(&inner, id, tenant, spec, &mut sink),
                    }
                };
                match out {
                    Ok(result) => {
                        let doc = result.to_json();
                        match validate_result(&doc) {
                            Ok(()) => sink.send(Event::Result {
                                job: id,
                                result: Box::new(result),
                            }),
                            Err(e) => sink.send(Event::Error {
                                job: id,
                                message: format!("engine produced a schema-invalid result: {e:#}"),
                                retry_after_ms: None,
                            }),
                        }
                    }
                    Err(e) => {
                        let retry = retry_after_ms(&e);
                        let message = if is_cancelled(&e) {
                            "cancelled".to_string()
                        } else if is_overloaded(&e) {
                            "overloaded".to_string()
                        } else {
                            format!("{e:#}")
                        };
                        sink.send(Event::Error {
                            job: id,
                            message,
                            retry_after_ms: retry,
                        });
                    }
                }
            });
        // A spawn failure (thread exhaustion) is a job failure, not a
        // panic: the handle still delivers a well-formed terminal event.
        let join = match join {
            Ok(j) => Some(j),
            Err(e) => {
                let _ = spawn_tx.send(Event::Error {
                    job: id,
                    message: format!("could not spawn a job thread: {e}"),
                    retry_after_ms: None,
                });
                None
            }
        };
        drop(spawn_tx);
        JobHandle {
            id,
            rx,
            cancel,
            join,
        }
    }
}

impl Inner {
    /// Wait for a job slot, polling the cancel token so a queued job can
    /// be cancelled before it ever starts.
    fn acquire_slot(&self, cancel: &CancelToken) -> Result<SlotGuard<'_>> {
        let mut active = self.active.lock().unwrap();
        loop {
            if cancel.is_cancelled() {
                return Err(Cancelled.into());
            }
            if *active < self.budget.runs_parallel {
                *active += 1;
                return Ok(SlotGuard(self));
            }
            let (guard, _) = self
                .gate
                .wait_timeout(active, Duration::from_millis(50))
                .unwrap();
            active = guard;
        }
    }

    /// `(train, test)` datasets, cached across jobs.
    fn data(
        &self,
        kind: DataKind,
        train_n: Option<usize>,
        test_n: Option<usize>,
    ) -> (Dataset, Dataset) {
        let n = train_n.unwrap_or(self.cfg.scale.n_train);
        let m = test_n.unwrap_or(self.cfg.scale.n_test);
        let key = format!("{}-{n}-{m}", kind.name());
        if let Some(pair) = self.data.lock().unwrap().get(&key) {
            return pair.clone();
        }
        let pair = make_data(kind, n, m);
        self.data
            .lock()
            .unwrap()
            .entry(key)
            .or_insert(pair)
            .clone()
    }

    /// A backend factory for `(kind, variant)`, reusing the engine's
    /// resolved native cores across jobs.
    fn factory(&self, kind: BackendKind, variant: &str) -> Result<BackendFactory> {
        let spec = EngineSpec::new(kind, variant).with_artifacts_dir(&self.cfg.artifacts_dir);
        match kind {
            // The full auto path (PJRT with native fallback) only when the
            // probe saw a usable PJRT; otherwise auto is native below.
            BackendKind::Pjrt => return spec.factory(),
            BackendKind::Auto if self.pjrt_available => return spec.factory(),
            _ => {}
        }
        if let Some(shared) = self.shared.lock().unwrap().get(variant).cloned() {
            return Ok(BackendFactory::from_native_shared(spec, shared));
        }
        let f = EngineSpec::new(BackendKind::Native, variant)
            .with_artifacts_dir(&self.cfg.artifacts_dir)
            .factory()?;
        if let Some(shared) = f.native_shared() {
            self.shared
                .lock()
                .unwrap()
                .insert(variant.to_string(), shared);
        }
        Ok(f)
    }

    /// Kernel threads each job's native workers get. One slot keeps the
    /// process default (whole machine / `AIRBENCH_NATIVE_THREADS`);
    /// multiple slots take the planned per-slot share.
    fn kernel_share(&self) -> usize {
        if self.budget.runs_parallel <= 1 {
            0
        } else {
            self.budget.kernel_threads
        }
    }

    /// Spawn a worker under the engine's slot budget.
    fn spawn_worker(&self, factory: &BackendFactory) -> Result<Box<dyn Backend>> {
        if factory.supports_parallel() {
            Ok(factory.spawn_send(self.kernel_share())?)
        } else {
            factory.spawn()
        }
    }

    /// The request batcher of a warm model, created on first use. Keyed by
    /// `id@content_hash`: re-loading different weights under the same id
    /// gets a fresh batcher (the stale one is dropped — its worker drains
    /// and exits), while every `predict_one` against the same weights
    /// shares one coalescing queue.
    fn batcher(&self, warm: &WarmModel) -> Result<Arc<Batcher>> {
        let key = format!("{}@{}", warm.id, warm.content_hash);
        let mut batchers = self.batchers.lock().unwrap();
        if let Some(b) = batchers.get(&key) {
            return Ok(Arc::clone(b));
        }
        let mut cfg = self.cfg.batcher;
        cfg.kernel_threads = self.kernel_share();
        let b = Arc::new(Batcher::new(
            Arc::clone(&warm.shared),
            Arc::clone(&warm.state),
            cfg,
            Arc::clone(&self.metrics),
        )?);
        // One batcher per live (id, weights) pair: a replaced entry under
        // the same id is evicted so the map stays proportional to warm
        // models, not to load history.
        batchers.retain(|k, _| !k.starts_with(&format!("{}@", warm.id)) || k == &key);
        batchers.insert(key, Arc::clone(&b));
        Ok(b)
    }
}

fn exec(
    inner: &Inner,
    id: JobId,
    tenant: u64,
    spec: JobSpec,
    sink: &mut ChannelSink,
) -> Result<JobResult> {
    match spec {
        JobSpec::Train(job) => exec_train(inner, id, job, sink),
        JobSpec::Eval(job) => exec_eval(inner, id, job, sink),
        JobSpec::Fleet(job) => exec_fleet(inner, id, job, sink),
        JobSpec::Study(job) => exec_study(inner, id, job, sink),
        JobSpec::FleetShard(job) => exec_fleet_shard(inner, id, job, sink),
        JobSpec::Health(job) => exec_health(inner, id, job, sink),
        JobSpec::Bench(job) => exec_bench(inner, id, job, sink),
        JobSpec::FleetBench(job) => exec_fleet_bench(inner, id, job, sink),
        JobSpec::Info(job) => exec_info(inner, id, job, sink),
        JobSpec::Save(job) => exec_save(inner, id, job, sink),
        JobSpec::Load(job) => exec_load(inner, id, job, sink),
        JobSpec::Predict(job) => exec_predict(inner, id, job, sink),
        JobSpec::PredictOne(job) => exec_predict_one(inner, id, tenant, job, sink),
        JobSpec::Metrics(job) => exec_metrics(inner, id, job, sink),
        JobSpec::ServeBench(job) => exec_serve_bench(inner, id, job, sink),
    }
}

fn started(sink: &mut ChannelSink, id: JobId, kind: &str, backend: &str, variant: &str) {
    sink.send(Event::Started {
        job: id,
        kind: kind.to_string(),
        backend: backend.to_string(),
        variant: variant.to_string(),
    });
}

fn exec_train(
    inner: &Inner,
    id: JobId,
    job: TrainJob,
    sink: &mut ChannelSink,
) -> Result<JobResult> {
    let cfg = job.config;
    let (train_ds, test_ds) = inner.data(job.data, job.train_n, job.test_n);
    let factory = inner.factory(cfg.backend, &cfg.variant)?;
    started(sink, id, "train", factory.kind().name(), &cfg.variant);
    let mut engine = inner.spawn_worker(&factory)?;
    sink.on_log(&format!(
        "[airbench] backend={} variant={} params={} compile={:.2}s train_n={} test_n={}",
        engine.name(),
        cfg.variant,
        engine.variant().param_count,
        engine.stats().compile_secs,
        train_ds.len(),
        test_ds.len()
    ));
    if job.warmup {
        warmup(engine.as_mut(), &train_ds, &cfg)?;
    }
    let (result, state) = train_run(engine.as_mut(), &train_ds, &test_ds, &cfg, sink)?;
    let mut checkpoint = None;
    if let Some(path) = &job.save {
        let saved = checkpoint::save(&state, engine.variant(), Some(&cfg.to_json()), path)
            .with_context(|| format!("saving checkpoint {}", path.display()))?;
        sink.on_log(&format!(
            "checkpoint written to {} (payload {}, md5 {})",
            path.display(),
            saved.payload_path.display(),
            saved.content_hash
        ));
        checkpoint = Some(path.clone());
    }
    Ok(JobResult::Train {
        result,
        config: cfg,
        backend: factory.kind().name().to_string(),
        checkpoint,
    })
}

fn exec_eval(inner: &Inner, id: JobId, job: EvalJob, sink: &mut ChannelSink) -> Result<JobResult> {
    let cfg = job.config;
    // Either checkpoint format: the versioned manifest+payload, or the
    // legacy ABCK1 state file.
    let state = if checkpoint::is_checkpoint(&job.load) {
        checkpoint::load(&job.load, &inner.cfg.artifacts_dir)
            .with_context(|| format!("loading checkpoint {}", job.load.display()))?
            .state
    } else {
        ModelState::load(&job.load)
            .with_context(|| format!("loading checkpoint {}", job.load.display()))?
    };
    let (_, test_ds) = inner.data(job.data, None, job.test_n);
    let factory = inner.factory(cfg.backend, &cfg.variant)?;
    started(sink, id, "eval", factory.kind().name(), &cfg.variant);
    let mut engine = inner.spawn_worker(&factory)?;
    state.validate(engine.variant())?;
    if job.precision != EvalPrecision::F32 {
        // Non-default precision must be honored or refused — never
        // silently evaluated at f32 (the trait default rejects bf16).
        engine.set_eval_precision(job.precision)?;
        sink.on_log(&format!("[eval] precision={}", job.precision.name()));
    }
    let out = evaluate_observed(engine.as_mut(), &state, &test_ds, cfg.tta, sink)?;
    Ok(JobResult::Eval {
        accuracy: out.accuracy,
        accuracy_no_tta: out.accuracy_identity,
        n_test: test_ds.len(),
        checkpoint: job.load,
        backend: factory.kind().name().to_string(),
    })
}

fn exec_fleet(
    inner: &Inner,
    id: JobId,
    job: FleetJob,
    sink: &mut ChannelSink,
) -> Result<JobResult> {
    let cfg = job.config;
    let runs = job.runs.unwrap_or(inner.cfg.scale.runs);
    let parallel = job.parallel.unwrap_or(cfg.fleet_parallel);
    let (train_ds, test_ds) = inner.data(job.data, job.train_n, job.test_n);
    let factory = inner.factory(cfg.backend, &cfg.variant)?;
    started(sink, id, "fleet", factory.kind().name(), &cfg.variant);
    // Coordinator mode (dist_workers set): shard the seed table across the
    // remote serve workers instead of training here. The merged result is
    // bit-identical to the local paths below — same seeds, seed-ordered
    // merge (DESIGN.md §13).
    if !cfg.dist_workers.is_empty() {
        let pool = WorkerPool::parse(&cfg.dist_workers, cfg.dist_timeout_s)?;
        sink.on_log(&format!(
            "[fleet] distributed: workers={} runs={} shard_timeout={:.0}s",
            pool.addrs.len(),
            runs,
            pool.timeout.as_secs_f64(),
        ));
        let remote = RemoteJob {
            cfg: &cfg,
            data: job.data,
            train_n: job.train_n,
            test_n: job.test_n,
            data_hash: Some(dataset_fingerprint(&train_ds, &test_ds)),
        };
        let fleet =
            run_fleet_remote(&pool, &remote, runs, Some(&mut *sink as &mut dyn Observer))?;
        let mut log_path = None;
        if let Some(path) = &job.log {
            std::fs::write(path, fleet.to_json(&cfg).to_string())
                .with_context(|| format!("writing fleet log {}", path.display()))?;
            sink.on_log(&format!("fleet log written to {}", path.display()));
            log_path = Some(path.clone());
        }
        return Ok(JobResult::Fleet {
            result: fleet,
            config: cfg,
            backend: factory.kind().name().to_string(),
            log: log_path,
        });
    }
    // The one resolver the scheduler itself uses — what we report is what
    // runs (env override, auto, PJRT sequential collapse included).
    let budget = fleet_budget(&factory, parallel, runs);
    sink.on_log(&format!(
        "[fleet] backend={} parallel={} kernel_threads={} cores={}",
        factory.kind().name(),
        budget.runs_parallel,
        budget.kernel_threads,
        budget.cores,
    ));
    let concurrent = budget.runs_parallel > 1 && runs > 1;
    let fleet = if concurrent {
        if job.warmup {
            // Pay one-time costs (pool spawn, allocators) on a throwaway
            // worker — native workers are an Arc clone, so this is free.
            let mut w = factory.spawn()?;
            warmup(w.as_mut(), &train_ds, &cfg)?;
        }
        run_fleet_parallel(
            &factory,
            &train_ds,
            &test_ds,
            &cfg,
            runs,
            parallel,
            Some(&mut *sink as &mut dyn Observer),
        )?
    } else {
        // Sequential: keep the (possibly compiled-once PJRT) worker alive
        // across warmup and every run, on its budgeted kernel share.
        let mut engine: Box<dyn Backend> = if factory.supports_parallel() {
            factory.spawn_send(budget.kernel_threads)?
        } else {
            factory.spawn()?
        };
        if job.warmup {
            warmup(engine.as_mut(), &train_ds, &cfg)?;
        }
        run_fleet(
            engine.as_mut(),
            &train_ds,
            &test_ds,
            &cfg,
            runs,
            Some(&mut *sink as &mut dyn Observer),
        )?
    };
    let mut log_path = None;
    if let Some(path) = &job.log {
        std::fs::write(path, fleet.to_json(&cfg).to_string())
            .with_context(|| format!("writing fleet log {}", path.display()))?;
        sink.on_log(&format!("fleet log written to {}", path.display()));
        log_path = Some(path.clone());
    }
    Ok(JobResult::Fleet {
        result: fleet,
        config: cfg,
        backend: factory.kind().name().to_string(),
        log: log_path,
    })
}

fn exec_study(
    inner: &Inner,
    id: JobId,
    job: StudyJob,
    sink: &mut ChannelSink,
) -> Result<JobResult> {
    let cfg = job.config;
    let runs = job.runs.unwrap_or(inner.cfg.scale.runs);
    let parallel = job.parallel.unwrap_or(cfg.fleet_parallel);
    let (train_ds, test_ds) = inner.data(job.data, job.train_n, job.test_n);
    let factory = inner.factory(cfg.backend, &cfg.variant)?;
    started(sink, id, "study", factory.kind().name(), &cfg.variant);
    // Coordinator mode: shard every cell's fleet across the remote serve
    // workers; the merged grid (and the written report) is byte-identical
    // to the local path below (DESIGN.md §13).
    if !cfg.dist_workers.is_empty() {
        let pool = WorkerPool::parse(&cfg.dist_workers, cfg.dist_timeout_s)?;
        sink.on_log(&format!(
            "[study] distributed: workers={} cells={} runs={} shard_timeout={:.0}s",
            pool.addrs.len(),
            job.policies.len(),
            runs,
            pool.timeout.as_secs_f64(),
        ));
        let remote = RemoteJob {
            cfg: &cfg,
            data: job.data,
            train_n: job.train_n,
            test_n: job.test_n,
            data_hash: Some(dataset_fingerprint(&train_ds, &test_ds)),
        };
        let result = run_study_remote(
            &pool,
            &remote,
            &job.policies,
            runs,
            Some(&mut *sink as &mut dyn Observer),
        )?;
        let mut log_path = None;
        if let Some(path) = &job.log {
            std::fs::write(path, result.to_json(&cfg, factory.kind().name()).to_string())
                .with_context(|| format!("writing study report {}", path.display()))?;
            sink.on_log(&format!("study report written to {}", path.display()));
            log_path = Some(path.clone());
        }
        return Ok(JobResult::Study {
            result,
            config: cfg,
            backend: factory.kind().name().to_string(),
            log: log_path,
        });
    }
    let budget = fleet_budget(&factory, parallel, runs);
    sink.on_log(&format!(
        "[study] backend={} cells={} runs={} parallel={} kernel_threads={}",
        factory.kind().name(),
        job.policies.len(),
        runs,
        budget.runs_parallel,
        budget.kernel_threads,
    ));
    if job.warmup {
        // Pay one-time costs once for the whole grid — every cell shares
        // the same resolved cores.
        let mut w = factory.spawn()?;
        warmup(w.as_mut(), &train_ds, &cfg)?;
    }
    let result = run_study(
        &factory,
        &train_ds,
        &test_ds,
        &cfg,
        &job.policies,
        runs,
        parallel,
        Some(&mut *sink as &mut dyn Observer),
    )?;
    let mut log_path = None;
    if let Some(path) = &job.log {
        std::fs::write(path, result.to_json(&cfg, factory.kind().name()).to_string())
            .with_context(|| format!("writing study report {}", path.display()))?;
        sink.on_log(&format!("study report written to {}", path.display()));
        log_path = Some(path.clone());
    }
    Ok(JobResult::Study {
        result,
        config: cfg,
        backend: factory.kind().name().to_string(),
        log: log_path,
    })
}

/// Worker side of a distributed fleet/study (DESIGN.md §13): train exactly
/// the coordinator-shipped seed slice and return the per-run scalar
/// vectors in slice order. The coordinator already applied any policy, so
/// the config is a plain fleet config; the dataset is verified against the
/// coordinator's content fingerprint *before* any training, failing with
/// the typed [`RemoteError::DataMismatch`] — a mismatched worker must
/// never contribute runs.
fn exec_fleet_shard(
    inner: &Inner,
    id: JobId,
    job: FleetShardJob,
    sink: &mut ChannelSink,
) -> Result<JobResult> {
    let cfg = job.config;
    let (train_ds, test_ds) = inner.data(job.data, job.train_n, job.test_n);
    if let Some(expect) = &job.data_hash {
        let got = dataset_fingerprint(&train_ds, &test_ds);
        if &got != expect {
            let base: Result<()> = Err(RemoteError::DataMismatch.err());
            return Err(base
                .context(format!(
                    "this worker's dataset fingerprint {got} does not match the \
                     coordinator's {expect} (check the data kind and \
                     AIRBENCH_TRAIN_N/AIRBENCH_TEST_N on both sides)"
                ))
                .unwrap_err());
        }
    }
    let factory = inner.factory(cfg.backend, &cfg.variant)?;
    started(sink, id, "fleet_shard", factory.kind().name(), &cfg.variant);
    let parallel = job.parallel.unwrap_or(cfg.fleet_parallel);
    let budget = fleet_budget(&factory, parallel, job.seeds.len());
    sink.on_log(&format!(
        "[shard {}] backend={} runs={} start={} parallel={} kernel_threads={}",
        job.shard,
        factory.kind().name(),
        job.seeds.len(),
        job.start,
        budget.runs_parallel,
        budget.kernel_threads,
    ));
    let fleet = run_fleet_parallel_seeded(
        &factory,
        &train_ds,
        &test_ds,
        &cfg,
        &job.seeds,
        parallel,
        Some(&mut *sink as &mut dyn Observer),
    )?;
    Ok(JobResult::FleetShard {
        shard: job.shard,
        start: job.start,
        accs: fleet.accuracies,
        accs_no_tta: fleet.accuracies_no_tta,
        times: fleet.times,
        epochs_to_target: fleet.epochs_to_target,
    })
}

/// `{"job": "health"}` — rolling-window serving health: p50/p90/p99
/// request latency over (at most) the last `window_s` seconds, unlike the
/// cumulative `metrics` snapshot. Lightweight (bypasses the slot gate)
/// so health checks stay responsive while training jobs hold every slot.
fn exec_health(
    inner: &Inner,
    id: JobId,
    job: HealthJob,
    sink: &mut ChannelSink,
) -> Result<JobResult> {
    started(sink, id, "health", "-", "*");
    Ok(JobResult::Health {
        data: inner.metrics.health(job.window_s.unwrap_or(10)),
    })
}

fn exec_bench(
    _inner: &Inner,
    id: JobId,
    job: BenchJob,
    sink: &mut ChannelSink,
) -> Result<JobResult> {
    let c = &job.config;
    started(sink, id, "bench", c.backend.name(), &c.variant);
    sink.on_log(&format!(
        "[bench] backend={} variant={} runs={} steps={} warmup={} (§3.7 protocol)",
        c.backend.name(),
        c.variant,
        c.runs,
        c.steps,
        c.warmup_runs
    ));
    let report = crate::bench::run_observed(c, sink)?;
    let path = if job.write {
        Some(report.write(&c.out_dir)?)
    } else {
        None
    };
    Ok(JobResult::Bench { report, path })
}

fn exec_fleet_bench(
    _inner: &Inner,
    id: JobId,
    job: FleetBenchJob,
    sink: &mut ChannelSink,
) -> Result<JobResult> {
    let c = &job.config;
    started(sink, id, "fleet_bench", c.backend.name(), &c.variant);
    sink.on_log(&format!(
        "[bench] fleet phase: backend={} variant={} n_runs={} levels={:?}",
        c.backend.name(),
        c.variant,
        c.n_runs,
        c.parallel_levels
    ));
    let report = crate::bench::run_fleet_bench_observed(c, sink)?;
    let path = if job.write {
        Some(report.write(&c.out_dir)?)
    } else {
        None
    };
    Ok(JobResult::FleetBench { report, path })
}

// ---- artifact lifecycle: save / load / predict --------------------------

fn exec_save(inner: &Inner, id: JobId, job: SaveJob, sink: &mut ChannelSink) -> Result<JobResult> {
    // Resolve the source model: a warm registry entry, a versioned
    // checkpoint to re-serialize, or a legacy ABCK1 file to convert.
    let (state, shared, provenance): (Arc<ModelState>, Arc<NativeShared>, Json) =
        if let Some(key) = &job.model {
            let warm = inner.registry.get(key).ok_or_else(|| {
                anyhow!(
                    "no warm model '{key}' — submit a load job first (loaded: {:?})",
                    inner.registry.ids()
                )
            })?;
            (
                Arc::clone(&warm.state),
                Arc::clone(&warm.shared),
                warm.config.clone(),
            )
        } else if let Some(path) = &job.load {
            if checkpoint::is_checkpoint(path) {
                let loaded = checkpoint::load(path, &inner.cfg.artifacts_dir)
                    .with_context(|| format!("loading checkpoint {}", path.display()))?;
                (Arc::new(loaded.state), loaded.shared, loaded.config)
            } else {
                let state = ModelState::load(path)
                    .with_context(|| format!("loading legacy state {}", path.display()))?;
                let factory = inner.factory(BackendKind::Native, &job.config.variant)?;
                let shared = factory
                    .native_shared()
                    .ok_or_else(|| anyhow!("legacy conversion needs a native variant"))?;
                state.validate(shared.variant())?;
                (Arc::new(state), shared, job.config.to_json())
            }
        } else {
            bail!("save jobs need a 'model' registry id or a 'load' source path");
        };
    let variant = shared.variant();
    started(sink, id, "save", "-", &variant.name);
    let prov = match &provenance {
        Json::Null => None,
        j => Some(j),
    };
    let saved = checkpoint::save(&state, variant, prov, &job.out)
        .with_context(|| format!("saving checkpoint {}", job.out.display()))?;
    sink.on_log(&format!(
        "checkpoint written to {} (payload {}, md5 {})",
        saved.manifest_path.display(),
        saved.payload_path.display(),
        saved.content_hash
    ));
    Ok(JobResult::Save {
        path: saved.manifest_path,
        payload: saved.payload_path,
        content_hash: saved.content_hash,
        bytes: saved.payload_bytes,
        variant: variant.name.clone(),
    })
}

fn exec_load(inner: &Inner, id: JobId, job: LoadJob, sink: &mut ChannelSink) -> Result<JobResult> {
    // Verify the full chain (schema, length, hash, variant plan) BEFORE
    // touching the registry: a failed load leaves it exactly as it was.
    let loaded = checkpoint::load(&job.path, &inner.cfg.artifacts_dir)
        .with_context(|| format!("loading checkpoint {}", job.path.display()))?;
    let variant_name = loaded.shared.variant().name.clone();
    started(sink, id, "load", "-", &variant_name);
    let reg_id = job
        .id
        .clone()
        .unwrap_or_else(|| format!("m{}", &loaded.content_hash[..12]));
    let params = loaded.shared.variant().param_count;
    let tensors = loaded.state.tensors.len();
    let momenta = loaded.state.momenta.len();
    let warm = inner.registry.insert(WarmModel {
        id: reg_id,
        content_hash: loaded.content_hash,
        variant_name: variant_name.clone(),
        params,
        path: job.path.clone(),
        config: loaded.config,
        seed: loaded.seed,
        state: Arc::new(loaded.state),
        shared: loaded.shared,
    });
    sink.on_log(&format!(
        "model '{}' warm (variant {}, {} params, md5 {})",
        warm.id, warm.variant_name, warm.params, warm.content_hash
    ));
    Ok(JobResult::Load {
        id: warm.id.clone(),
        content_hash: warm.content_hash.clone(),
        variant: variant_name,
        params,
        path: job.path,
        tensors,
        momenta,
    })
}

fn exec_predict(
    inner: &Inner,
    id: JobId,
    job: PredictJob,
    sink: &mut ChannelSink,
) -> Result<JobResult> {
    if !job.models.is_empty() {
        return exec_predict_ensemble(inner, id, job, sink);
    }
    // Source: a warm registry entry (Arc clones, no IO) or an ad-hoc
    // checkpoint load (verified but not registered).
    let (state, shared, label, content_hash): (Arc<ModelState>, Arc<NativeShared>, String, String) =
        if let Some(key) = &job.model {
            let warm = inner.registry.get(key).ok_or_else(|| {
                anyhow!(
                    "no warm model '{key}' — submit a load job first (loaded: {:?})",
                    inner.registry.ids()
                )
            })?;
            (
                Arc::clone(&warm.state),
                Arc::clone(&warm.shared),
                warm.id.clone(),
                warm.content_hash.clone(),
            )
        } else if let Some(path) = &job.load {
            let loaded = checkpoint::load(path, &inner.cfg.artifacts_dir)
                .with_context(|| format!("loading checkpoint {}", path.display()))?;
            let hash = loaded.content_hash.clone();
            (
                Arc::new(loaded.state),
                loaded.shared,
                path.display().to_string(),
                hash,
            )
        } else {
            bail!("predict jobs need a 'model' registry id or a 'load' checkpoint path");
        };
    let variant_name = shared.variant().name.clone();
    // The loaded core IS the factory seam: every concurrent predict worker
    // on this model is an Arc clone of one resolved NativeShared.
    let spec = EngineSpec::new(BackendKind::Native, &variant_name)
        .with_artifacts_dir(&inner.cfg.artifacts_dir);
    let factory = BackendFactory::from_native_shared(spec, Arc::clone(&shared));
    started(sink, id, "predict", factory.kind().name(), &variant_name);
    let mut engine = inner.spawn_worker(&factory)?;
    state.validate(engine.variant())?;
    if job.precision != EvalPrecision::F32 {
        engine.set_eval_precision(job.precision)?;
        sink.on_log(&format!("[predict] precision={}", job.precision.name()));
    }
    let (_, test_ds) = inner.data(job.data, None, job.test_n);
    let out = evaluate_observed(engine.as_mut(), &state, &test_ds, job.tta, sink)?;
    Ok(JobResult::Predict {
        accuracy: out.accuracy,
        accuracy_no_tta: out.accuracy_identity,
        n_test: test_ds.len(),
        predictions: out.predictions,
        probs_md5: checkpoint::f32_md5(out.probs.data()),
        model: label,
        content_hash,
        variant: variant_name,
        backend: factory.kind().name().to_string(),
    })
}

/// Ensemble predict: probability-average two or more warm registry models
/// of the same variant. Each member runs its own full TTA pass; the
/// per-member softmax probabilities (and identity-view probabilities, for
/// the no-TTA readout) are averaged element-wise in f32, then argmaxed.
/// An ensemble of identical members is therefore *bitwise* equal to the
/// single model — `(p + p) / 2` is exact in f32 — which the parity test
/// pins.
fn exec_predict_ensemble(
    inner: &Inner,
    id: JobId,
    job: PredictJob,
    sink: &mut ChannelSink,
) -> Result<JobResult> {
    if job.model.is_some() || job.load.is_some() {
        bail!("predict takes either 'models' (ensemble) or a single 'model'/'load' source");
    }
    if job.models.len() < 2 {
        bail!("ensemble predict needs at least two 'models' entries");
    }
    let mut members = Vec::with_capacity(job.models.len());
    for key in &job.models {
        let warm = inner.registry.get(key).ok_or_else(|| {
            anyhow!(
                "no warm model '{key}' — submit a load job first (loaded: {:?})",
                inner.registry.ids()
            )
        })?;
        members.push(warm);
    }
    let variant_name = members[0].variant_name.clone();
    for m in &members[1..] {
        if m.variant_name != variant_name {
            bail!(
                "ensemble members must share a variant ('{}' is {}, '{}' is {})",
                members[0].id,
                variant_name,
                m.id,
                m.variant_name
            );
        }
    }
    started(sink, id, "predict", "native", &variant_name);
    let (_, test_ds) = inner.data(job.data, None, job.test_n);
    let n = test_ds.len();
    let k = test_ds.num_classes;
    let mut sum_probs = crate::tensor::Tensor::zeros(&[n, k]);
    let mut sum_identity = crate::tensor::Tensor::zeros(&[n, k]);
    for warm in &members {
        let spec = EngineSpec::new(BackendKind::Native, &variant_name)
            .with_artifacts_dir(&inner.cfg.artifacts_dir);
        let factory = BackendFactory::from_native_shared(spec, Arc::clone(&warm.shared));
        let mut engine = inner.spawn_worker(&factory)?;
        warm.state.validate(engine.variant())?;
        if job.precision != EvalPrecision::F32 {
            engine.set_eval_precision(job.precision)?;
        }
        let out = evaluate_observed(engine.as_mut(), &warm.state, &test_ds, job.tta, sink)?;
        sink.on_log(&format!(
            "[ensemble] member '{}' acc {:.4} (md5 {})",
            warm.id,
            out.accuracy,
            checkpoint::f32_md5(out.probs.data())
        ));
        for (dst, &src) in sum_probs.data_mut().iter_mut().zip(out.probs.data()) {
            *dst += src;
        }
        for (dst, &src) in sum_identity.data_mut().iter_mut().zip(out.probs_identity.data()) {
            *dst += src;
        }
    }
    let scale = 1.0 / members.len() as f32;
    for v in sum_probs.data_mut() {
        *v *= scale;
    }
    for v in sum_identity.data_mut() {
        *v *= scale;
    }
    let argmax_acc = |probs: &crate::tensor::Tensor| -> (Vec<u16>, f64) {
        let data = probs.data();
        let mut correct = 0usize;
        let mut preds = Vec::with_capacity(n);
        for i in 0..n {
            let row = &data[i * k..(i + 1) * k];
            let mut best = 0usize;
            for j in 1..k {
                if row[j] > row[best] {
                    best = j;
                }
            }
            preds.push(best as u16);
            if best == test_ds.labels[i] as usize {
                correct += 1;
            }
        }
        (preds, correct as f64 / n as f64)
    };
    let (predictions, accuracy) = argmax_acc(&sum_probs);
    let (_, accuracy_no_tta) = argmax_acc(&sum_identity);
    // The ensemble's identity is the hash of its members' hashes, in
    // request order — same members, same order, same hash.
    let joined = members
        .iter()
        .map(|m| m.content_hash.as_str())
        .collect::<Vec<_>>()
        .join(",");
    Ok(JobResult::Predict {
        accuracy,
        accuracy_no_tta,
        n_test: n,
        predictions,
        probs_md5: checkpoint::f32_md5(sum_probs.data()),
        model: job.models.join(","),
        content_hash: crate::util::md5::md5_hex(joined.as_bytes()),
        variant: variant_name,
        backend: "native".to_string(),
    })
}

// ---- serving tier: predict_one / metrics / serve_bench -------------------

/// One softmax row with the *same* f32 operation sequence as the
/// evaluator's `softmax_rows` — the `predict_one` probability row of an
/// image must be bit-identical to the row the unbatched predict path
/// computes for it.
fn softmax_row(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::MIN, f32::max);
    let mut sum = 0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

fn exec_predict_one(
    inner: &Inner,
    id: JobId,
    tenant: u64,
    job: PredictOneJob,
    sink: &mut ChannelSink,
) -> Result<JobResult> {
    let warm = inner.registry.get(&job.model).ok_or_else(|| {
        anyhow!(
            "no warm model '{}' — submit a load job first (loaded: {:?})",
            job.model,
            inner.registry.ids()
        )
    })?;
    started(sink, id, "predict_one", "native", &warm.variant_name);
    let batcher = inner.batcher(&warm)?;
    let (_, test_ds) = inner.data(job.data, None, job.test_n);
    if job.index >= test_ds.len() {
        bail!(
            "predict_one index {} is out of range (test split has {} images)",
            job.index,
            test_ds.len()
        );
    }
    let image = test_ds.images.image(job.index).to_vec();
    let t0 = Instant::now();
    let rx = batcher.submit(tenant, image)?;
    // The reply wait polls the cancel token: an admitted request cannot be
    // withdrawn from the batch (the batcher replies into a dropped
    // receiver, harmlessly), but the *job* stops promptly.
    let logits = loop {
        match rx.recv_timeout(Duration::from_millis(10)) {
            Ok(reply) => break reply?,
            Err(RecvTimeoutError::Timeout) => {
                if sink.cancelled() {
                    return Err(Cancelled.into());
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                bail!("the batcher shut down before replying")
            }
        }
    };
    let latency_us = t0.elapsed().as_secs_f64() * 1e6;
    inner.metrics.observe_request(latency_us);
    let mut probs = logits;
    softmax_row(&mut probs);
    let mut best = 0usize;
    for j in 1..probs.len() {
        if probs[j] > probs[best] {
            best = j;
        }
    }
    Ok(JobResult::PredictOne {
        model: warm.id.clone(),
        content_hash: warm.content_hash.clone(),
        variant: warm.variant_name.clone(),
        backend: "native".to_string(),
        index: job.index,
        prediction: best as u16,
        probs_md5: checkpoint::f32_md5(&probs),
        probs,
        latency_us,
    })
}

fn exec_metrics(
    inner: &Inner,
    id: JobId,
    _job: MetricsJob,
    sink: &mut ChannelSink,
) -> Result<JobResult> {
    started(sink, id, "metrics", "-", "*");
    Ok(JobResult::Metrics {
        data: inner.metrics.snapshot(),
    })
}

fn exec_serve_bench(
    _inner: &Inner,
    id: JobId,
    job: ServeBenchJob,
    sink: &mut ChannelSink,
) -> Result<JobResult> {
    let c = &job.config;
    started(sink, id, "serve_bench", "native", &c.variant);
    sink.on_log(&format!(
        "[bench] serve phase: variant={} clients={} requests={} levels={:?} max_wait_us={}",
        c.variant, c.clients, c.requests, c.max_batch_levels, c.max_wait_us
    ));
    let report = crate::bench::run_serve_bench_observed(c, sink)?;
    let path = if job.write {
        Some(report.write(&c.out_dir)?)
    } else {
        None
    };
    Ok(JobResult::ServeBench { report, path })
}

// ---- info --------------------------------------------------------------

fn variant_row(name: &str, source: &str, v: &crate::runtime::Variant) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("source", Json::str(source)),
        ("params", Json::num(v.param_count as f64)),
        ("batch_train", Json::num(v.batch_train as f64)),
        ("batch_eval", Json::num(v.batch_eval as f64)),
        (
            "fwd_mflops_per_example",
            Json::num(v.fwd_flops_per_example as f64 / 1e6),
        ),
    ])
}

fn variant_detail(name: &str, source: &str, v: &crate::runtime::Variant) -> Json {
    let mut j = variant_row(name, source, v);
    if let Json::Obj(m) = &mut j {
        m.insert(
            "widths".to_string(),
            Json::Arr(v.hyper.widths.iter().map(|&w| Json::num(w as f64)).collect()),
        );
        m.insert(
            "convs_per_block".to_string(),
            Json::num(v.hyper.convs_per_block as f64),
        );
        m.insert("residual".to_string(), Json::Bool(v.hyper.residual));
        m.insert(
            "tensors".to_string(),
            Json::Arr(
                v.tensors
                    .iter()
                    .map(|t| {
                        Json::obj(vec![
                            ("name", Json::str(&t.name)),
                            (
                                "shape",
                                Json::Arr(
                                    t.shape.iter().map(|&d| Json::num(d as f64)).collect(),
                                ),
                            ),
                            ("role", Json::str(&format!("{:?}", t.role))),
                            ("group", Json::str(&t.group)),
                        ])
                    })
                    .collect(),
            ),
        );
    }
    j
}

fn exec_info(inner: &Inner, id: JobId, job: InfoJob, sink: &mut ChannelSink) -> Result<JobResult> {
    started(sink, id, "info", "-", job.variant.as_deref().unwrap_or("*"));
    let dir = &inner.cfg.artifacts_dir;
    let manifest = Manifest::load(dir).ok();
    let mut variants: Vec<Json> = Vec::new();
    let mut extras: Vec<(&'static str, Json)> = Vec::new();
    match &job.variant {
        None => {
            if let Some(m) = &manifest {
                for (name, v) in &m.variants {
                    variants.push(variant_row(name, "manifest", v));
                }
            }
            for name in crate::runtime::native::builtin_names() {
                let v = crate::runtime::native::builtin_variant(name)
                    .expect("builtin name must resolve");
                variants.push(variant_row(name, "native", &v));
            }
        }
        Some(name) => {
            let (source, v) = match &manifest {
                Some(m) if m.variants.contains_key(name) => ("manifest", m.variant(name)?.clone()),
                _ => (
                    "native",
                    crate::runtime::native::builtin_variant(name).ok_or_else(|| {
                        anyhow!("variant '{name}' is neither in a manifest nor built-in")
                    })?,
                ),
            };
            variants.push(variant_detail(name, source, &v));
            if job.hlo {
                let Some(m) = &manifest else {
                    anyhow::bail!("--hlo needs built AOT artifacts (run `make artifacts`)");
                };
                let mv = m.variant(name)?;
                let mut hlo: Vec<(&'static str, Json)> = Vec::new();
                for (tag, file) in [("train", &mv.train.file), ("eval", &mv.eval.file)] {
                    let census = crate::util::hlo_census::census_file(&m.dir.join(file))?;
                    hlo.push((
                        tag,
                        Json::obj(vec![
                            ("instructions", Json::num(census.instructions as f64)),
                            ("computations", Json::num(census.computations as f64)),
                            (
                                "top_ops",
                                Json::Arr(
                                    census
                                        .top(12)
                                        .into_iter()
                                        .map(|(op, n)| {
                                            Json::obj(vec![
                                                ("op", Json::str(&op)),
                                                ("count", Json::num(n as f64)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ]),
                    ));
                }
                extras.push(("hlo", Json::obj(hlo)));
            }
        }
    }
    // What the native GEMM will run on this machine: the selected register
    // tile, the detected SIMD features, and the kernel thread default —
    // the same facts a BENCH env block records (schema v2).
    let simd = crate::runtime::native::simd::selected();
    let cpu = Json::obj(vec![
        ("arch", Json::str(std::env::consts::ARCH)),
        ("os", Json::str(std::env::consts::OS)),
        ("kernel", Json::str(simd.name())),
        (
            "features",
            Json::Arr(
                crate::runtime::native::simd::cpu_features()
                    .iter()
                    .map(|f| Json::str(f))
                    .collect(),
            ),
        ),
        (
            "threads",
            Json::num(crate::runtime::native::default_threads() as f64),
        ),
        ("cores", Json::num(available_cores() as f64)),
    ]);
    let mut pairs = vec![
        (
            "artifacts_dir",
            Json::str(&dir.display().to_string()),
        ),
        ("manifest", Json::Bool(manifest.is_some())),
        ("cpu", cpu),
        ("variants", Json::Arr(variants)),
    ];
    pairs.append(&mut extras);
    Ok(JobResult::Info {
        data: Json::obj(pairs),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::job::TrainJob;
    use crate::config::TrainConfig;

    fn nano_train(seed: u64) -> JobSpec {
        let mut cfg = TrainConfig::default();
        for (k, v) in [
            ("variant", "nano"),
            ("backend", "native"),
            ("epochs", "1"),
            ("tta", "none"),
            ("whiten_samples", "32"),
        ] {
            cfg.set(k, v).unwrap();
        }
        cfg.seed = seed;
        JobSpec::Train(TrainJob {
            config: cfg,
            train_n: Some(64),
            test_n: Some(32),
            warmup: false,
            ..TrainJob::default()
        })
    }

    fn test_engine(slots: usize) -> Engine {
        Engine::new(EngineConfig {
            job_slots: slots,
            ..EngineConfig::default()
        })
    }

    #[test]
    fn train_job_streams_a_wellformed_sequence() {
        let engine = test_engine(1);
        let handle = engine.submit(nano_train(3));
        let events: Vec<Event> = handle.events().collect();
        assert!(matches!(events.first(), Some(Event::Queued { .. })));
        assert!(matches!(events.get(1), Some(Event::Started { .. })));
        let terminal = events.last().expect("terminal event");
        match terminal {
            Event::Result { result, .. } => {
                validate_result(&result.to_json()).expect("schema-valid result");
                assert_eq!(result.kind_name(), "train");
            }
            other => panic!("expected result, got {other:?}"),
        }
        // Exactly one terminal, and it is last.
        assert_eq!(events.iter().filter(|e| e.is_terminal()).count(), 1);
        // eval_every_epoch is off, so epochs stream without val_acc.
        assert!(events
            .iter()
            .any(|e| matches!(e, Event::Epoch { val_acc: None, .. })));
    }

    #[test]
    fn bad_jobs_fail_as_error_events() {
        let engine = test_engine(1);
        let mut cfg = TrainConfig::default();
        cfg.variant = "no-such-variant".into();
        cfg.backend = BackendKind::Native;
        let handle = engine.submit(JobSpec::Train(TrainJob {
            config: cfg,
            ..TrainJob::default()
        }));
        let err = handle.wait().unwrap_err();
        assert!(format!("{err:#}").contains("no-such-variant"), "{err:#}");
    }

    #[test]
    fn cancelled_jobs_terminate_with_cancelled_error() {
        let engine = test_engine(1);
        let mut spec = nano_train(0);
        if let JobSpec::Train(t) = &mut spec {
            t.config.epochs = 10_000.0; // far longer than the test budget
        }
        let handle = engine.submit(spec);
        handle.cancel();
        let err = handle.wait().unwrap_err();
        assert_eq!(format!("{err}"), "cancelled");
    }

    #[test]
    fn info_job_lists_native_variants() {
        let engine = test_engine(1);
        let result = engine
            .submit(JobSpec::Info(InfoJob::default()))
            .wait()
            .expect("info result");
        let j = result.to_json();
        validate_result(&j).unwrap();
        let variants = j.get("data").unwrap().get("variants").unwrap().as_arr().unwrap();
        assert!(variants
            .iter()
            .any(|v| v.get("name").unwrap().as_str().unwrap() == "nano"));
    }
}
