//! Engine-level registry of warm models.
//!
//! A `load` job verifies a checkpoint once and parks it here as a
//! [`WarmModel`]: the weights behind an `Arc`, plus the resolved
//! [`NativeShared`] core — so every subsequent `predict` job is an
//! Arc-clone spawn (no file IO, no re-verification, no plan rebuild) and
//! any number of them can run concurrently under the engine's `job_slots`
//! budget against the same immutable weights.
//!
//! Models are keyed by a client-chosen id (default `m<hash prefix>`) and
//! are also addressable by their full content hash, so a client that only
//! knows *what* model it wants (the payload MD5) need not know what the
//! loader called it. Failed loads never touch the registry.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::runtime::{ModelState, NativeShared};
use crate::util::json::Json;

/// One verified, loaded model held warm by the engine.
pub struct WarmModel {
    /// Registry key the model is addressed by.
    pub id: String,
    /// Lowercase MD5 of the checkpoint payload — the model's identity.
    pub content_hash: String,
    /// Variant the weights belong to.
    pub variant_name: String,
    /// Parameter count from the variant plan.
    pub params: usize,
    /// Manifest path the model was loaded from.
    pub path: PathBuf,
    /// Config provenance from the checkpoint (`Json::Null` when unknown).
    pub config: Json,
    /// Seed provenance from the checkpoint (`""` when unknown).
    pub seed: String,
    /// The weights, shared read-only by every predict worker.
    pub state: Arc<ModelState>,
    /// The resolved native core — what makes a predict spawn Arc-cheap.
    pub shared: Arc<NativeShared>,
}

/// Warm models keyed by id, also addressable by content hash.
#[derive(Default)]
pub struct Registry {
    models: Mutex<BTreeMap<String, Arc<WarmModel>>>,
}

impl Registry {
    /// Insert (or replace) a model under its id; returns the shared handle.
    pub fn insert(&self, model: WarmModel) -> Arc<WarmModel> {
        let arc = Arc::new(model);
        self.models
            .lock()
            .unwrap()
            .insert(arc.id.clone(), Arc::clone(&arc));
        arc
    }

    /// Look up by exact id first, then by exact content hash.
    pub fn get(&self, key: &str) -> Option<Arc<WarmModel>> {
        let models = self.models.lock().unwrap();
        if let Some(m) = models.get(key) {
            return Some(Arc::clone(m));
        }
        models.values().find(|m| m.content_hash == key).map(Arc::clone)
    }

    /// Registered ids, sorted.
    pub fn ids(&self) -> Vec<String> {
        self.models.lock().unwrap().keys().cloned().collect()
    }

    /// Number of warm models.
    pub fn len(&self) -> usize {
        self.models.lock().unwrap().len()
    }

    /// Whether no model is warm.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::builtin_variant;
    use crate::runtime::{InitConfig, ModelState};

    fn warm(id: &str, hash: &str) -> WarmModel {
        let variant = builtin_variant("nano").unwrap();
        let state = ModelState::init(&variant, &InitConfig::default());
        WarmModel {
            id: id.to_string(),
            content_hash: hash.to_string(),
            variant_name: "nano".to_string(),
            params: variant.param_count,
            path: PathBuf::from("model.ckpt"),
            config: Json::Null,
            seed: String::new(),
            state: Arc::new(state),
            shared: Arc::new(NativeShared::new(variant)),
        }
    }

    #[test]
    fn lookup_by_id_and_by_content_hash() {
        let reg = Registry::default();
        assert!(reg.is_empty());
        reg.insert(warm("a", "00000000000000000000000000000001"));
        reg.insert(warm("b", "00000000000000000000000000000002"));
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.ids(), vec!["a".to_string(), "b".to_string()]);
        assert_eq!(reg.get("a").unwrap().content_hash, "00000000000000000000000000000001");
        let by_hash = reg.get("00000000000000000000000000000002").unwrap();
        assert_eq!(by_hash.id, "b");
        assert!(reg.get("missing").is_none());
    }

    #[test]
    fn reinsert_replaces_under_the_same_id() {
        let reg = Registry::default();
        reg.insert(warm("m", "00000000000000000000000000000001"));
        reg.insert(warm("m", "00000000000000000000000000000002"));
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.get("m").unwrap().content_hash, "00000000000000000000000000000002");
    }
}
