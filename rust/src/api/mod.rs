//! The public job API: one engine surface for every workload.
//!
//! This layer is the programmatic face of the crate (DESIGN.md §9). A
//! client — the CLI, the `airbench serve` daemon, a test, or library code
//! — builds a typed [`JobSpec`] (train / eval / fleet / study / bench /
//! fleet-bench / info, plus the artifact lifecycle save / load /
//! predict, DESIGN.md §10), submits it to an [`Engine`], and consumes a
//! typed [`Event`] stream from the returned [`JobHandle`]:
//!
//! ```text
//! queued -> started -> (epoch | run | log)* -> result | error
//! ```
//!
//! Every spec and event has a total JSON mapping, so the same documents
//! drive the in-process API and the NDJSON serve protocol. Results are
//! uniform `{"kind", "data"}` envelopes ([`JobResult`]) and are
//! schema-checked ([`validate_result`]) before they are emitted.
//!
//! # Example
//!
//! Train the `nano` variant on synthetic data and read the result:
//!
//! ```
//! use airbench::api::{Engine, EngineConfig, JobResult, JobSpec, TrainJob};
//!
//! let mut job = TrainJob::default();
//! job.config.set("variant", "nano").unwrap();
//! job.config.set("backend", "native").unwrap();
//! job.config.set("epochs", "1").unwrap();
//! job.config.set("tta", "none").unwrap();
//! job.config.set("whiten_samples", "32").unwrap();
//! job.train_n = Some(64);
//! job.test_n = Some(32);
//! job.warmup = false;
//!
//! let engine = Engine::new(EngineConfig::default());
//! let result = engine.submit(JobSpec::Train(job)).wait().unwrap();
//! match result {
//!     JobResult::Train { result, .. } => assert!(result.accuracy >= 0.0),
//!     other => panic!("unexpected result kind {other:?}"),
//! }
//! ```

pub mod engine;
pub mod event;
pub mod job;
pub mod registry;

pub use engine::{CancelToken, Engine, EngineConfig, JobHandle};
pub use event::{validate_result, Event, JobId, JobResult};
pub use job::{
    BenchJob, EvalJob, FleetBenchJob, FleetJob, FleetShardJob, HealthJob, InfoJob, JobSpec,
    LoadJob, MetricsJob, PredictJob, PredictOneJob, SaveJob, ServeBenchJob, StudyJob, TrainJob,
};
pub use registry::{Registry, WarmModel};
