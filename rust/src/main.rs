//! `airbench` — CLI launcher for the Rust airbench stack.
//!
//! Subcommands:
//! * `train [key=value ...]` — one training run with per-epoch logging
//!   (the paper's Listing 4 `main`), printing the final TTA accuracy and
//!   the paper-protocol wall time.
//! * `fleet --runs N [--parallel P] [key=value ...]` — an n-run
//!   statistical experiment: mean/std/CI of final accuracy (paper §5
//!   methodology). `--parallel` trains P runs concurrently on
//!   factory-spawned workers under the global thread budget — per-run
//!   results are bit-identical at every P (DESIGN.md §8).
//! * `bench [--runs N] [--steps N] [--tag T]` — the §3.7 benchmark
//!   harness: per-phase medians and seed-distribution stats, written as
//!   `BENCH_<tag>.json` (see BENCHMARKS.md for protocol and schema).
//!   `bench --fleet` times the same fleet at several parallelism levels
//!   (the fleet-throughput phase, `airbench.fleet-bench/1` schema).
//! * `info [--variant NAME]` — inspect the AOT manifest when artifacts are
//!   built, else the native backend's built-in variant table.
//!
//! Config overrides are bare `key=value` pairs (see `config::TrainConfig`);
//! `--config file.json` loads a base config first. `--data` picks the
//! dataset distribution (cifar10 | cifar100 | imagenet | svhn | cinic).
//! `--backend auto|pjrt|native` picks the execution backend (DESIGN.md §2):
//! `auto` (default) uses the compiled PJRT path when artifacts + runtime
//! exist and falls back to the pure-Rust native backend otherwise.

use anyhow::{bail, Result};

use airbench::cli::Args;
use airbench::config::TrainConfig;
use airbench::coordinator::{evaluate, train_full, warmup};
use airbench::experiments::{pct, DataKind, Lab};
use airbench::runtime::Backend;
use airbench::util::logging;

fn parse_data_kind(s: &str) -> Result<DataKind> {
    Ok(match s {
        "cifar10" => DataKind::Cifar10,
        "cifar100" => DataKind::Cifar100Like,
        "imagenet" => DataKind::ImagenetLike,
        "svhn" => DataKind::SvhnLike,
        "cinic" => DataKind::CinicLike,
        _ => bail!("unknown --data '{s}' (cifar10|cifar100|imagenet|svhn|cinic)"),
    })
}

fn build_config(args: &Args, lab: &Lab) -> Result<TrainConfig> {
    let mut cfg = match args.options.get("config") {
        Some(path) => TrainConfig::load(std::path::Path::new(path))?,
        None => TrainConfig {
            epochs: lab.scale.epochs,
            ..TrainConfig::default()
        },
    };
    for (k, v) in &args.overrides {
        cfg.set(k, v)?;
    }
    // Flag spellings of config keys:
    // `--backend auto|pjrt|native` picks the execution backend;
    // `--workers N` enables the parallel prefetching pipeline with N
    // worker threads — bit-identical batches to the synchronous loader
    // (DESIGN.md §5); `--prefetch-depth N` caps how many batches each
    // worker runs ahead.
    if let Some(b) = args.options.get("backend") {
        cfg.set("backend", b)?;
    }
    if let Some(w) = args.options.get("workers") {
        cfg.set("workers", w)?;
    }
    if let Some(d) = args.options.get("prefetch-depth") {
        cfg.set("prefetch_depth", d)?;
    }
    Ok(cfg)
}

fn lab_and_config(args: &Args) -> Result<(Lab, TrainConfig)> {
    let mut lab = Lab::new()?;
    let cfg = build_config(args, &lab)?;
    // Precedence: an explicit `--backend`/`backend=` (anything but the
    // `auto` default) beats AIRBENCH_BACKEND; plain `auto` defers to the
    // env-derived kind Lab::new already read.
    if cfg.backend != airbench::runtime::BackendKind::Auto {
        lab.set_backend(cfg.backend);
    }
    Ok((lab, cfg))
}

fn cmd_train(args: &Args) -> Result<()> {
    let (mut lab, mut cfg) = lab_and_config(args)?;
    cfg.eval_every_epoch = true;
    let kind = parse_data_kind(&args.opt("data", "cifar10"))?;
    let (train_ds, test_ds) = lab.data(kind);
    let engine = lab.backend(&cfg.variant)?;
    eprintln!(
        "[airbench] backend={} variant={} params={} compile={:.2}s train_n={} test_n={}",
        engine.name(),
        cfg.variant,
        engine.variant().param_count,
        engine.stats().compile_secs,
        train_ds.len(),
        test_ds.len()
    );
    if !args.flag("no-warmup") {
        warmup(engine, &train_ds, &cfg)?;
    }

    logging::print_header(logging::TRAIN_COLUMNS);
    let (result, state) = train_full(engine, &train_ds, &test_ds, &cfg)?;
    for log in &result.epoch_log {
        logging::print_row(
            logging::TRAIN_COLUMNS,
            &[
                ("epoch", log.epoch.to_string()),
                ("train_loss", logging::f4(log.train_loss as f32)),
                ("train_acc", logging::f4(log.train_acc as f32)),
                (
                    "val_acc",
                    log.val_acc.map(|a| logging::f4(a as f32)).unwrap_or_default(),
                ),
            ],
            false,
        );
    }
    logging::print_row(
        logging::TRAIN_COLUMNS,
        &[
            ("epoch", "eval".to_string()),
            ("tta_val_acc", logging::f4(result.accuracy as f32)),
            ("total_time_seconds", format!("{:.3}", result.time_seconds)),
        ],
        true,
    );
    println!(
        "final: acc={} (no-TTA {}), epochs={:.2}, steps={}, {:.3}s, {:.2} GFLOP",
        pct(result.accuracy),
        pct(result.accuracy_no_tta),
        result.epochs_run,
        result.steps_run,
        result.time_seconds,
        result.flops as f64 / 1e9,
    );
    if let Some(e) = result.epochs_to_target {
        println!("epochs-to-target({}): {e:.1}", pct(cfg.target_acc));
    }
    if let Some(path) = args.options.get("save") {
        state.save(std::path::Path::new(path))?;
        println!("checkpoint written to {path}");
    }
    Ok(())
}

/// `airbench eval --load ckpt.bin [--data cifar10] [tta=2 ...]` —
/// evaluate a saved checkpoint (checkpoint/hand-off workflow). Checkpoints
/// are backend-portable: a model trained on pjrt evaluates on native and
/// vice versa (shared `ModelState` layout, DESIGN.md §2).
fn cmd_eval(args: &Args) -> Result<()> {
    let (mut lab, cfg) = lab_and_config(args)?;
    let kind = parse_data_kind(&args.opt("data", "cifar10"))?;
    let Some(path) = args.options.get("load") else {
        bail!("eval requires --load <checkpoint>");
    };
    let state = airbench::runtime::ModelState::load(std::path::Path::new(path))?;
    let (_, test_ds) = lab.data(kind);
    let engine = lab.backend(&cfg.variant)?;
    state.validate(engine.variant())?;
    let out = evaluate(engine, &state, &test_ds, cfg.tta)?;
    println!(
        "checkpoint {path}: acc={} (no-TTA {}) on {} test examples",
        pct(out.accuracy),
        pct(out.accuracy_identity),
        test_ds.len()
    );
    Ok(())
}

fn cmd_fleet(args: &Args) -> Result<()> {
    let (mut lab, cfg) = lab_and_config(args)?;
    let kind = parse_data_kind(&args.opt("data", "cifar10"))?;
    let runs = args.opt_usize("runs", lab.scale.runs)?;
    // `--parallel N` / `--fleet-parallel N` (or the `fleet_parallel` config
    // key / AIRBENCH_FLEET_PARALLEL env): concurrent runs. 0 = auto.
    let parallel = match args
        .options
        .get("parallel")
        .or_else(|| args.options.get("fleet-parallel"))
    {
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("--parallel expects an integer, got '{v}'"))?,
        None => cfg.fleet_parallel,
    };
    let (train_ds, test_ds) = lab.data(kind);
    let factory = airbench::runtime::EngineSpec::new(lab.kind(), &cfg.variant)
        .with_artifacts_dir(lab.artifacts_dir())
        .factory()?;
    // The one resolver the scheduler itself uses — what we print is what
    // runs (env override, auto, PJRT sequential collapse included).
    let budget = airbench::coordinator::fleet_budget(&factory, parallel, runs);
    eprintln!(
        "[fleet] backend={} parallel={} kernel_threads={} cores={}",
        factory.kind().name(),
        budget.runs_parallel,
        budget.kernel_threads,
        budget.cores,
    );
    let mut progress = |i: usize, acc: f64| {
        eprintln!("[fleet] run {i}: {}", pct(acc));
    };
    let concurrent = budget.runs_parallel > 1 && runs > 1;
    let fleet = if concurrent {
        // Pay one-time costs (pool spawn, allocators) on a throwaway
        // worker — native workers are an Arc clone, so this is free.
        {
            let mut w = factory.spawn()?;
            warmup(w.as_mut(), &train_ds, &cfg)?;
        }
        airbench::coordinator::run_fleet_parallel(
            &factory,
            &train_ds,
            &test_ds,
            &cfg,
            runs,
            parallel,
            Some(&mut progress),
        )?
    } else {
        // Sequential: keep the (possibly compiled-once PJRT) worker alive
        // across warmup and every run. Native engines take the budgeted
        // kernel share so the banner above describes what actually runs.
        let mut engine: Box<dyn airbench::runtime::Backend> = if factory.supports_parallel() {
            factory.spawn_send(budget.kernel_threads)?
        } else {
            factory.spawn()?
        };
        warmup(engine.as_mut(), &train_ds, &cfg)?;
        airbench::coordinator::run_fleet(
            engine.as_mut(),
            &train_ds,
            &test_ds,
            &cfg,
            runs,
            Some(&mut progress),
        )?
    };
    let s = fleet.summary();
    println!(
        "fleet n={}: mean={} std={:.3}% ci95=±{:.3}% min={} max={} mean_time={:.2}s",
        s.n,
        pct(s.mean),
        100.0 * s.std,
        100.0 * s.ci95(),
        pct(s.min),
        pct(s.max),
        fleet.mean_time_seconds(),
    );
    if let Some(path) = args.options.get("log") {
        std::fs::write(path, fleet.to_json(&cfg).to_string())?;
        println!("fleet log written to {path}");
    }
    Ok(())
}

/// `airbench bench [--backend B] [--variant V] [--runs N] [--steps N]
/// [--warmup N] [--epochs E] [--workers N] [--tag T] [--out DIR]` — run the
/// §3.7 harness and write `BENCH_<tag>.json` (BENCHMARKS.md).
fn cmd_bench(args: &Args) -> Result<()> {
    if args.flag("fleet") {
        return cmd_bench_fleet(args);
    }
    let mut cfg = airbench::bench::BenchConfig::default();
    if let Some(v) = args.options.get("variant") {
        cfg.variant = v.clone();
    }
    let backend = args.opt("backend", "auto");
    cfg.backend = airbench::runtime::BackendKind::parse(&backend)
        .ok_or_else(|| anyhow::anyhow!("unknown --backend '{backend}' (auto|pjrt|native)"))?;
    cfg.runs = args.opt_usize("runs", cfg.runs)?.max(1);
    cfg.steps = args.opt_usize("steps", cfg.steps)?.max(1);
    cfg.warmup_runs = args.opt_usize("warmup", cfg.warmup_runs)?;
    cfg.epochs = args.opt_f64("epochs", cfg.epochs)?;
    cfg.workers = args.opt_usize("workers", cfg.workers)?;
    cfg.train_n = args.opt_usize("train-n", cfg.train_n)?;
    cfg.test_n = args.opt_usize("test-n", cfg.test_n)?;
    if let Some(t) = args.options.get("tag") {
        cfg.tag = Some(t.clone());
    }
    if let Some(o) = args.options.get("out") {
        cfg.out_dir = std::path::PathBuf::from(o);
    }

    eprintln!(
        "[bench] backend={} variant={} runs={} steps={} warmup={} (§3.7 protocol)",
        cfg.backend.name(),
        cfg.variant,
        cfg.runs,
        cfg.steps,
        cfg.warmup_runs
    );
    let report = airbench::bench::run(&cfg)?;
    let row = |name: &str, d: &airbench::bench::Dist, unit: &str| {
        let s = d.summary();
        println!(
            "  {name:<16} median {:>9.2}{unit}  mean {:>9.2}  std {:>7.2}  min {:>9.2}  max {:>9.2}  (n={})",
            d.median(),
            s.mean,
            s.std,
            s.min,
            s.max,
            s.n
        );
    };
    println!(
        "bench report: backend={} variant={} threads={} batch={}",
        report.backend_name, report.variant, report.threads, report.batch_train
    );
    row("train_step_ms", &report.step_ms, "ms");
    row("init_ms", &report.init_ms, "ms");
    row("eval_ms", &report.eval_ms, "ms");
    row("run_s", &report.run_s, "s");
    row("run_train_s", &report.run_train_s, "s");
    row("run_eval_s", &report.run_eval_s, "s");
    println!(
        "  step throughput: {:.2} GFLOP/s effective, {:.0} img/s",
        report.train_gflops(),
        report.batch_train as f64 / (report.step_ms.median() * 1e-3).max(1e-12),
    );
    let path = report.write(&cfg.out_dir)?;
    println!("wrote {}", path.display());
    Ok(())
}

/// `airbench bench --fleet [--fleet-runs N] [--parallel-levels 1,2,4]
/// [--variant V] [--backend B] [--epochs E] [--tag T] [--out DIR]` — time
/// the same n-run fleet at several `--fleet-parallel` levels and write a
/// `BENCH_<tag>.json` with the `airbench.fleet-bench/1` schema.
fn cmd_bench_fleet(args: &Args) -> Result<()> {
    let d = airbench::bench::FleetBenchConfig::default();
    let backend = args.opt("backend", "auto");
    let cfg = airbench::bench::FleetBenchConfig {
        variant: args.opt("variant", &d.variant),
        backend: airbench::runtime::BackendKind::parse(&backend)
            .ok_or_else(|| anyhow::anyhow!("unknown --backend '{backend}' (auto|pjrt|native)"))?,
        tag: args.options.get("tag").cloned(),
        n_runs: args.opt_usize("fleet-runs", d.n_runs)?.max(1),
        parallel_levels: args.opt_usize_list("parallel-levels", &d.parallel_levels)?,
        epochs: args.opt_f64("epochs", d.epochs)?,
        train_n: args.opt_usize("train-n", d.train_n)?,
        test_n: args.opt_usize("test-n", d.test_n)?,
        out_dir: args
            .options
            .get("out")
            .map(std::path::PathBuf::from)
            .unwrap_or(d.out_dir),
    };
    eprintln!(
        "[bench] fleet phase: backend={} variant={} n_runs={} levels={:?}",
        cfg.backend.name(),
        cfg.variant,
        cfg.n_runs,
        cfg.parallel_levels
    );
    let report = airbench::bench::run_fleet_bench(&cfg)?;
    println!(
        "fleet bench: backend={} variant={} n_runs={} cores={}",
        report.backend_name, report.variant, cfg.n_runs, report.cores
    );
    for l in &report.levels {
        println!(
            "  parallel {:>2} (x{} kernel threads): {:>7.2}s wall, {:>6.2} runs/s, \
             speedup {:>5.2}x, mean acc {:.4}, bit-identical: {}",
            l.parallel,
            l.kernel_threads,
            l.wall_s,
            l.runs_per_s,
            l.speedup_vs_p1,
            l.mean_acc,
            l.bit_identical_to_p1
        );
    }
    let path = report.write(&cfg.out_dir)?;
    println!("wrote {}", path.display());
    Ok(())
}

fn print_variant_row(name: &str, v: &airbench::runtime::Variant) {
    println!(
        "  {name:<20} params={:<9} batch={}x{} fwd={:.1} MFLOP/example",
        v.param_count,
        v.batch_train,
        v.batch_eval,
        v.fwd_flops_per_example as f64 / 1e6
    );
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = airbench::runtime::Manifest::default_dir();
    let manifest = airbench::runtime::Manifest::load(&dir).ok();
    match args.options.get("variant") {
        None => {
            match &manifest {
                Some(m) => {
                    println!("AOT variants in {:?}:", m.dir);
                    for (name, v) in &m.variants {
                        print_variant_row(name, v);
                    }
                }
                None => {
                    println!("no AOT artifacts in {dir:?} (run `make artifacts`)");
                }
            }
            println!("native built-in variants (--backend native):");
            for name in airbench::runtime::native::builtin_names() {
                print_variant_row(
                    name,
                    &airbench::runtime::native::builtin_variant(name).unwrap(),
                );
            }
        }
        Some(name) => {
            let v = match &manifest {
                Some(m) if m.variants.contains_key(name) => m.variant(name)?.clone(),
                _ => airbench::runtime::native::builtin_variant(name).ok_or_else(|| {
                    anyhow::anyhow!("variant '{name}' is neither in a manifest nor built-in")
                })?,
            };
            if args.flag("hlo") {
                let Some(m) = &manifest else {
                    bail!("--hlo needs built AOT artifacts (run `make artifacts`)");
                };
                let mv = m.variant(name)?;
                for (tag, file) in [("train", &mv.train.file), ("eval", &mv.eval.file)] {
                    let census = airbench::util::hlo_census::census_file(&m.dir.join(file))?;
                    println!(
                        "{tag} module: {} instructions, {} computations; top ops:",
                        census.instructions, census.computations
                    );
                    for (op, n) in census.top(12) {
                        println!("    {op:<24} {n}");
                    }
                }
                return Ok(());
            }
            println!(
                "variant {name}: widths={:?} convs_per_block={} residual={}",
                v.hyper.widths, v.hyper.convs_per_block, v.hyper.residual
            );
            println!(
                "  params={} fwd_flops/example={}",
                v.param_count, v.fwd_flops_per_example
            );
            println!("  tensors:");
            for t in &v.tensors {
                println!(
                    "    {:<20} {:?} role={:?} group={}",
                    t.name, t.shape, t.role, t.group
                );
            }
        }
    }
    Ok(())
}

fn usage() {
    eprintln!(
        "usage: airbench <train|eval|fleet|bench|info> [--data cifar10] [--runs N] \
         [--config file.json] [--backend auto|pjrt|native] [--workers N] \
         [--prefetch-depth N] [--parallel N] [--save ckpt.bin] [--load ckpt.bin] \
         [--log fleet.json] [--hlo] [key=value ...]\n       airbench --version\n\
         \n\
         bench               run the §3.7 benchmark harness and write \
         BENCH_<tag>.json (options: --runs --steps --warmup --epochs \
         --tag --out --train-n --test-n; see BENCHMARKS.md)\n\
         bench --fleet       fleet-throughput phase: time the same n-run \
         fleet at several parallelism levels (--fleet-runs N \
         --parallel-levels 1,2,4) and write a fleet-schema BENCH_<tag>.json\n\
         --backend KIND      execution backend (also config key `backend`): \
         auto = compiled PJRT when artifacts + runtime exist, else the \
         pure-Rust native backend; pjrt / native force one\n\
         --workers N         augment batches on N background threads \
         (0 = on the train thread; output is bit-identical either way)\n\
         --prefetch-depth N  batches each worker may run ahead (default 2)\n\
         --parallel N        (fleet; alias --fleet-parallel, config key \
         `fleet_parallel`) concurrent runs, budgeted so runs x kernel \
         threads <= cores; 0 = auto. Per-run results are bit-identical \
         at every value\n\
         \n\
         env: AIRBENCH_BACKEND=auto|pjrt|native, AIRBENCH_NATIVE_THREADS=N \
         (native kernel threads; outputs bit-identical at any value), \
         AIRBENCH_FLEET_PARALLEL=N (fleet auto-parallelism override)"
    );
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    if args.flag("version") {
        println!("airbench {}", airbench::version());
        return Ok(());
    }
    match args.command.as_deref() {
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("bench") => cmd_bench(&args),
        Some("info") => cmd_info(&args),
        _ => {
            usage();
            std::process::exit(2);
        }
    }
}
