//! `airbench` — CLI launcher for the Rust airbench stack, as a **thin
//! client of the job API** (DESIGN.md §9).
//!
//! Every command builds a typed [`JobSpec`], submits it through
//! [`Engine::submit`], and renders the resulting [`Event`] stream — as
//! human-readable text by default, or as raw NDJSON with `--json` (one
//! event object per line; the terminal `result` event carries the
//! schema-validated `{"kind", "data"}` result envelope). The commands and
//! the generated usage text come from one [`Command`] table, so help and
//! dispatch cannot diverge.
//!
//! Subcommands (see `airbench` with no arguments for the full flag list):
//! * `train [key=value ...]` — one training run with per-epoch logging.
//! * `eval --load ckpt.bin` — evaluate a saved checkpoint.
//! * `predict --model ID | --load model.ckpt` — logits/accuracy from a
//!   warm or on-disk model (DESIGN.md §10).
//! * `save` / `load` — write / register versioned checkpoint artifacts
//!   (content-hashed payload + schema-validated manifest).
//! * `fleet --runs N [--parallel P]` — an n-run statistical experiment
//!   (`--workers host:port,...` shards it across remote serve workers
//!   with a bit-identical merged result, DESIGN.md §13).
//! * `study --policies a,b [--runs N]` — an augmentation-policy × seed
//!   grid with per-cell CIs and seed-paired comparisons (DESIGN.md §11).
//! * `bench [--fleet]` — the §3.7 benchmark harness (BENCHMARKS.md).
//! * `info [--variant NAME]` — inspect the AOT manifest / variant table.
//! * `serve [--addr host:port] [--slots N]` — the long-lived job daemon:
//!   newline-delimited JSON `JobSpec`s in, `Event` JSON out. `--max-batch`
//!   / `--max-wait-us` / `--queue-cap` shape the predict micro-batcher
//!   (DESIGN.md §12).
//! * `metrics` — the serving counters/latency snapshot (the CLI face of
//!   the serve-protocol `{"job":"metrics"}` endpoint).
//! * `health` — rolling-window request-latency view over the last N
//!   seconds (`{"job":"health"}`).
//!
//! Config resolution follows the documented precedence **CLI > env >
//! config file > default** (`config::resolve`): bare `key=value` pairs
//! and flag spellings (`--backend`, `--workers`, ...) form the CLI layer,
//! `AIRBENCH_*` variables the env layer, `--config file.json` the file
//! layer.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use airbench::api::{
    BenchJob, Engine, EngineConfig, EvalJob, Event, FleetBenchJob, FleetJob, HealthJob, InfoJob,
    JobResult, JobSpec, LoadJob, MetricsJob, PredictJob, SaveJob, ServeBenchJob, StudyJob,
    TrainJob,
};
use airbench::cli::{find_command, Args, Command};
use airbench::config::{process_env, ConfigLayers, TrainConfig, TtaLevel};
use airbench::data::augment::Policy;
use airbench::experiments::{pct, DataKind, Scale};
use airbench::runtime::EvalPrecision;
use airbench::util::json::{parse as parse_json, Json};
use airbench::util::logging;

// ---------------------------------------------------------------------------
// The command table: usage text AND dispatch are generated from these rows.
// ---------------------------------------------------------------------------

static COMMANDS: &[Command] = &[
    Command {
        name: "train",
        summary: "one training run with per-epoch logging (paper Listing 4 main)",
        run: cmd_train,
    },
    Command {
        name: "eval",
        summary: "evaluate a saved checkpoint (--load ckpt.bin; backend-portable)",
        run: cmd_eval,
    },
    Command {
        name: "predict",
        summary: "logits/accuracy from a warm model id or checkpoint (--model ID | --load ckpt)",
        run: cmd_predict,
    },
    Command {
        name: "save",
        summary: "write a versioned checkpoint artifact (--out model.ckpt; manifest + payload)",
        run: cmd_save,
    },
    Command {
        name: "load",
        summary: "load + verify a checkpoint into the warm-model registry (--path model.ckpt)",
        run: cmd_load,
    },
    Command {
        name: "fleet",
        summary: "n-run statistical experiment (--runs N --parallel P; paper §5)",
        run: cmd_fleet,
    },
    Command {
        name: "study",
        summary: "augmentation-policy x seed grid with paired comparisons (--policies a,b --runs N)",
        run: cmd_study,
    },
    Command {
        name: "bench",
        summary: "§3.7 benchmark harness writing BENCH_<tag>.json (--fleet | --serve phases)",
        run: cmd_bench,
    },
    Command {
        name: "info",
        summary: "inspect the AOT manifest / built-in variant table (--variant NAME --hlo)",
        run: cmd_info,
    },
    Command {
        name: "serve",
        summary: "job daemon: JobSpec JSON lines in (stdin or --addr), event JSON out",
        run: cmd_serve,
    },
    Command {
        name: "metrics",
        summary: "serving counters + latency quantiles from an engine ({\"job\":\"metrics\"})",
        run: cmd_metrics,
    },
    Command {
        name: "health",
        summary: "rolling-window serve latency over the last N seconds ({\"job\":\"health\"})",
        run: cmd_health,
    },
];

const FLAG_HELP: &str = "\
common flags:\n\
  --json              emit the raw event stream as NDJSON (one JSON object\n\
                      per line; the terminal `result` event carries the\n\
                      schema-validated result envelope)\n\
  --config file.json  config-file layer (precedence: CLI > env > config\n\
                      file > default)\n\
  --data KIND         dataset distribution (cifar10|cifar100|imagenet|svhn|cinic)\n\
  --variant NAME      model variant (also config key `variant`)\n\
  --backend KIND      execution backend (also config key `backend`):\n\
                      auto = compiled PJRT when artifacts + runtime exist,\n\
                      else the pure-Rust native backend; pjrt / native force one\n\
  --workers N         augment batches on N background threads (0 = on the\n\
                      train thread; output is bit-identical either way)\n\
  --prefetch-depth N  batches each worker may run ahead (default 2)\n\
  --seed N            RNG seed (config key `seed`)\n\
\n\
train:  --save model.ckpt --no-warmup [key=value ...] (writes the\n\
        versioned manifest + payload pair, DESIGN.md §10)\n\
eval:   --load ckpt (versioned model.ckpt or legacy ckpt.bin),\n\
        --precision f32|bf16 (bf16: half-storage GEMM operands,\n\
        f32 accumulate — eval only, native backend)\n\
predict: --model ID | --load model.ckpt | --models a,b,c (ensemble:\n\
        probability-average over warm registry entries),\n\
        --tta none|mirror|multicrop, --test-n N, --precision f32|bf16\n\
save:   --out model.ckpt, source: --model ID | --load ckpt\n\
load:   --path model.ckpt --id NAME (default id m<hash12>)\n\
fleet:  --runs N --log fleet.json --parallel N (alias --fleet-parallel,\n\
        config key `fleet_parallel`): concurrent runs budgeted so\n\
        runs x kernel threads <= cores; 0 = auto. Per-run results are\n\
        bit-identical at every value (DESIGN.md §8).\n\
        --workers host:port,host:port shards the runs across remote\n\
        `serve --addr` workers (config key `dist_workers`; merged result\n\
        bit-identical to local, DESIGN.md §13); --dist-timeout-s T sets\n\
        the per-shard deadline (default 600)\n\
study:  --policies a,b,... (comma-separated compact spellings: flip mode\n\
        [none|random|alternating|alternating_md5] then key=value\n\
        segments crop=heavy|light|center:N, translate=N, cutout=N,\n\
        sub=wide|rcut:N; e.g. 'random+crop=light+sub=rcut:6'),\n\
        --runs N --log study.json --parallel N. Every cell runs the SAME\n\
        forked seed table, so comparisons are seed-paired (DESIGN.md §11).\n\
        --workers host:port,... distributes cells shard-wise like fleet\n\
bench:  --runs --steps --warmup --epochs --tag --out --train-n --test-n\n\
        (see BENCHMARKS.md); bench --fleet adds --fleet-runs N\n\
        --parallel-levels 1,2,4; bench --serve adds --clients N\n\
        --requests N --max-batch-levels 1,8,32 --max-wait-us T\n\
        --queue-cap N (serve-bench load phase, BENCHMARKS.md)\n\
info:   --variant NAME --hlo\n\
serve:  --addr host:port (TCP; default: stdin/stdout NDJSON session)\n\
        --slots N concurrent job slots (default 0 = auto: one per core;\n\
        each job's kernels get cores/slots threads)\n\
        --max-batch N coalesce up to N predict_one requests per batched\n\
        eval call (0 = model eval batch), --max-wait-us T flush deadline\n\
        (latency SLO, default 2000), --queue-cap N admission queue bound\n\
        (overfull submissions get a typed `overloaded` rejection)\n\
metrics: (in-process snapshot; over serve, send {\"job\":\"metrics\"})\n\
health: --window-s N rolling latency window in seconds (default 10;\n\
        over serve, send {\"job\":\"health\",\"window_s\":N})\n\
\n\
env:    AIRBENCH_BACKEND / AIRBENCH_VARIANT / AIRBENCH_EPOCHS /\n\
        AIRBENCH_WORKERS / AIRBENCH_PREFETCH_DEPTH /\n\
        AIRBENCH_FLEET_PARALLEL / AIRBENCH_DIST_WORKERS /\n\
        AIRBENCH_DIST_TIMEOUT_S / AIRBENCH_SEED form the env layer;\n\
        AIRBENCH_NATIVE_THREADS=N sets native kernel threads (outputs\n\
        bit-identical at any value); AIRBENCH_FORCE_SCALAR=1 pins the\n\
        portable scalar GEMM tile (skips AVX2 dispatch);\n\
        AIRBENCH_TRAIN_N / AIRBENCH_TEST_N /\n\
        AIRBENCH_RUNS scale the default datasets and fleet size";

fn usage() {
    eprintln!("usage: airbench <command> [--flags] [key=value ...]\n       airbench --version\n");
    eprintln!("commands:");
    for c in COMMANDS {
        eprintln!("  {:<8} {}", c.name, c.summary);
    }
    eprintln!("\n{FLAG_HELP}");
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    if args.flag("version") {
        println!("airbench {}", airbench::version());
        return Ok(());
    }
    match args.command.as_deref().and_then(|name| find_command(COMMANDS, name)) {
        Some(cmd) => (cmd.run)(&args),
        None => {
            usage();
            std::process::exit(2);
        }
    }
}

// ---------------------------------------------------------------------------
// Spec building (the one config resolver + flag spellings)
// ---------------------------------------------------------------------------

/// Resolve the run config for this invocation: defaults (epoch budget from
/// the env scale) < `--config file.json` < `AIRBENCH_*` env < CLI
/// (`key=value` overrides, then flag spellings — the flag wins when both
/// are given).
fn resolved_config(args: &Args) -> Result<TrainConfig> {
    let scale = Scale::from_env();
    let base = TrainConfig {
        epochs: scale.epochs,
        ..TrainConfig::default()
    };
    let file_json = match args.options.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading config file {path}"))?;
            Some(parse_json(&text).with_context(|| format!("parsing config file {path}"))?)
        }
        None => None,
    };
    let mut cli: Vec<(String, String)> = args.overrides.clone();
    for (flag, key) in [
        ("variant", "variant"),
        ("backend", "backend"),
        ("epochs", "epochs"),
        ("prefetch-depth", "prefetch_depth"),
        ("parallel", "fleet_parallel"),
        ("fleet-parallel", "fleet_parallel"),
        ("dist-timeout-s", "dist_timeout_s"),
        ("seed", "seed"),
    ] {
        if let Some(v) = args.options.get(flag) {
            cli.push((key.to_string(), v.clone()));
        }
    }
    // `--workers` is overloaded by value: `host:port[,host:port]` names a
    // remote serve-worker pool (config key `dist_workers` — the distributed
    // coordinator, DESIGN.md §13), while a plain integer keeps the original
    // meaning of data-pipeline threads (config key `workers`).
    if let Some(v) = args.options.get("workers") {
        let key = if v.contains(':') { "dist_workers" } else { "workers" };
        cli.push((key.to_string(), v.clone()));
    }
    TrainConfig::resolve(ConfigLayers {
        base,
        file: file_json.as_ref(),
        env: &process_env,
        cli: &cli,
    })
}

fn data_kind(args: &Args) -> Result<DataKind> {
    let s = args.opt("data", "cifar10");
    DataKind::parse(&s)
        .ok_or_else(|| anyhow::anyhow!("unknown --data '{s}' (cifar10|cifar100|imagenet|svhn|cinic)"))
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = resolved_config(args)?;
    cfg.eval_every_epoch = true;
    let spec = JobSpec::Train(TrainJob {
        config: cfg,
        data: data_kind(args)?,
        train_n: None,
        test_n: None,
        warmup: !args.flag("no-warmup"),
        save: args.options.get("save").map(PathBuf::from),
    });
    run_and_render(args, spec)
}

fn eval_precision(args: &Args) -> Result<EvalPrecision> {
    let s = args.opt("precision", "f32");
    EvalPrecision::parse(&s).ok_or_else(|| anyhow::anyhow!("unknown --precision '{s}' (f32|bf16)"))
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = resolved_config(args)?;
    let Some(path) = args.options.get("load") else {
        bail!("eval requires --load <checkpoint>");
    };
    let spec = JobSpec::Eval(EvalJob {
        config: cfg,
        data: data_kind(args)?,
        load: PathBuf::from(path),
        test_n: None,
        precision: eval_precision(args)?,
    });
    run_and_render(args, spec)
}

fn cmd_predict(args: &Args) -> Result<()> {
    let model = args.options.get("model").cloned();
    let load = args.options.get("load").map(PathBuf::from);
    let models: Vec<String> = args
        .options
        .get("models")
        .map(|s| s.split(',').map(|m| m.trim().to_string()).filter(|m| !m.is_empty()).collect())
        .unwrap_or_default();
    if model.is_none() && load.is_none() && models.is_empty() {
        bail!(
            "predict requires --model <registry id>, --load <checkpoint>, \
             or --models <id,id,...> (ensemble)"
        );
    }
    let tta_s = args.opt("tta", "none");
    let Some(tta) = TtaLevel::parse(&tta_s) else {
        bail!("unknown --tta '{tta_s}' (0|none|1|mirror|2|multicrop)");
    };
    let test_n = match args.options.get("test-n") {
        Some(_) => Some(args.opt_usize("test-n", 0)?),
        None => None,
    };
    let spec = JobSpec::Predict(PredictJob {
        model,
        load,
        models,
        data: data_kind(args)?,
        test_n,
        tta,
        precision: eval_precision(args)?,
    });
    run_and_render(args, spec)
}

fn cmd_save(args: &Args) -> Result<()> {
    let Some(out) = args.options.get("out") else {
        bail!("save requires --out <manifest path> (e.g. --out model.ckpt)");
    };
    let model = args.options.get("model").cloned();
    let load = args.options.get("load").map(PathBuf::from);
    if model.is_none() && load.is_none() {
        bail!("save requires a source: --model <registry id> or --load <checkpoint>");
    }
    let spec = JobSpec::Save(SaveJob {
        model,
        load,
        out: PathBuf::from(out),
        config: resolved_config(args)?,
    });
    run_and_render(args, spec)
}

fn cmd_load(args: &Args) -> Result<()> {
    let Some(path) = args.options.get("path").or_else(|| args.options.get("load")) else {
        bail!("load requires --path <checkpoint manifest> (alias --load)");
    };
    let spec = JobSpec::Load(LoadJob {
        path: PathBuf::from(path),
        id: args.options.get("id").cloned(),
    });
    run_and_render(args, spec)
}

fn cmd_fleet(args: &Args) -> Result<()> {
    let cfg = resolved_config(args)?;
    let runs = args.opt_usize("runs", Scale::from_env().runs)?;
    let spec = JobSpec::Fleet(FleetJob {
        config: cfg,
        data: data_kind(args)?,
        runs: Some(runs),
        parallel: None, // the resolver already folded --parallel into the config
        train_n: None,
        test_n: None,
        warmup: true,
        log: args.options.get("log").map(PathBuf::from),
    });
    run_and_render(args, spec)
}

fn cmd_study(args: &Args) -> Result<()> {
    let cfg = resolved_config(args)?;
    let runs = args.opt_usize("runs", Scale::from_env().runs)?;
    let spelled = args.opt("policies", "random,alternating");
    let policies = spelled
        .split(',')
        .map(|s| Policy::parse(s.trim()))
        .collect::<Result<Vec<_>>>()
        .context("parsing --policies")?;
    let spec = JobSpec::Study(StudyJob {
        config: cfg,
        data: data_kind(args)?,
        policies,
        runs: Some(runs),
        parallel: None, // the resolver already folded --parallel into the config
        train_n: None,
        test_n: None,
        warmup: true,
        log: args.options.get("log").map(PathBuf::from),
    });
    run_and_render(args, spec)
}

fn parse_backend_flag(args: &Args) -> Result<airbench::runtime::BackendKind> {
    let backend = args.opt("backend", "auto");
    airbench::runtime::BackendKind::parse(&backend)
        .ok_or_else(|| anyhow::anyhow!("unknown --backend '{backend}' (auto|pjrt|native)"))
}

fn cmd_bench(args: &Args) -> Result<()> {
    if args.flag("fleet") {
        return cmd_bench_fleet(args);
    }
    if args.flag("serve") {
        return cmd_bench_serve(args);
    }
    let d = airbench::bench::BenchConfig::default();
    let config = airbench::bench::BenchConfig {
        variant: args.opt("variant", &d.variant),
        backend: parse_backend_flag(args)?,
        tag: args.options.get("tag").cloned(),
        warmup_runs: args.opt_usize("warmup", d.warmup_runs)?,
        runs: args.opt_usize("runs", d.runs)?.max(1),
        steps: args.opt_usize("steps", d.steps)?.max(1),
        epochs: args.opt_f64("epochs", d.epochs)?,
        train_n: args.opt_usize("train-n", d.train_n)?,
        test_n: args.opt_usize("test-n", d.test_n)?,
        workers: args.opt_usize("workers", d.workers)?,
        out_dir: args.options.get("out").map(PathBuf::from).unwrap_or(d.out_dir),
    };
    run_and_render(args, JobSpec::Bench(BenchJob { config, write: true }))
}

fn cmd_bench_fleet(args: &Args) -> Result<()> {
    let d = airbench::bench::FleetBenchConfig::default();
    let config = airbench::bench::FleetBenchConfig {
        variant: args.opt("variant", &d.variant),
        backend: parse_backend_flag(args)?,
        tag: args.options.get("tag").cloned(),
        n_runs: args.opt_usize("fleet-runs", d.n_runs)?.max(1),
        parallel_levels: args.opt_usize_list("parallel-levels", &d.parallel_levels)?,
        epochs: args.opt_f64("epochs", d.epochs)?,
        train_n: args.opt_usize("train-n", d.train_n)?,
        test_n: args.opt_usize("test-n", d.test_n)?,
        out_dir: args.options.get("out").map(PathBuf::from).unwrap_or(d.out_dir),
    };
    run_and_render(args, JobSpec::FleetBench(FleetBenchJob { config, write: true }))
}

fn cmd_bench_serve(args: &Args) -> Result<()> {
    let d = airbench::bench::ServeBenchConfig::default();
    let config = airbench::bench::ServeBenchConfig {
        variant: args.opt("variant", &d.variant),
        tag: args.options.get("tag").cloned(),
        clients: args.opt_usize("clients", d.clients)?.max(1),
        requests: args.opt_usize("requests", d.requests)?.max(1),
        max_batch_levels: args.opt_usize_list("max-batch-levels", &d.max_batch_levels)?,
        max_wait_us: args.opt_u64("max-wait-us", d.max_wait_us)?,
        queue_cap: args.opt_usize("queue-cap", d.queue_cap)?.max(1),
        test_n: args.opt_usize("test-n", d.test_n)?.max(1),
        out_dir: args.options.get("out").map(PathBuf::from).unwrap_or(d.out_dir),
    };
    run_and_render(args, JobSpec::ServeBench(ServeBenchJob { config, write: true }))
}

fn cmd_info(args: &Args) -> Result<()> {
    let spec = JobSpec::Info(InfoJob {
        variant: args.options.get("variant").cloned(),
        hlo: args.flag("hlo"),
    });
    run_and_render(args, spec)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let bd = airbench::serve::batcher::BatcherConfig::default();
    let engine = Engine::new(EngineConfig {
        job_slots: args.opt_usize("slots", 0)?,
        batcher: airbench::serve::batcher::BatcherConfig {
            max_batch: args.opt_usize("max-batch", bd.max_batch)?,
            max_wait_us: args.opt_u64("max-wait-us", bd.max_wait_us)?,
            queue_cap: args.opt_usize("queue-cap", bd.queue_cap)?.max(1),
            ..bd
        },
        ..EngineConfig::default()
    });
    if let Some(addr) = args.options.get("addr") {
        let listener = std::net::TcpListener::bind(addr)
            .with_context(|| format!("binding serve address {addr}"))?;
        eprintln!(
            "[serve] listening on {} ({} job slots)",
            listener.local_addr()?,
            engine.job_slots()
        );
        airbench::serve::serve_tcp(&engine, listener)
    } else {
        eprintln!(
            "[serve] reading newline-delimited JobSpec JSON from stdin ({} job slots)",
            engine.job_slots()
        );
        let stats = airbench::serve::serve_stdin(&engine)?;
        eprintln!(
            "[serve] session done: {} submitted, {} rejected, {} cancelled",
            stats.submitted, stats.rejected, stats.cancelled
        );
        Ok(())
    }
}

fn cmd_metrics(args: &Args) -> Result<()> {
    // An in-process engine starts with zeroed counters; the command exists
    // so the CLI mirrors the serve-protocol `{"job":"metrics"}` endpoint
    // (and so `--json` shows the exact snapshot schema).
    run_and_render(args, JobSpec::Metrics(MetricsJob))
}

fn cmd_health(args: &Args) -> Result<()> {
    // Same role as `metrics` for the `{"job":"health"}` endpoint: the
    // rolling-window latency view (last N seconds, not since start).
    let window_s = match args.options.get("window-s") {
        Some(_) => Some(args.opt_u64("window-s", 10)?),
        None => None,
    };
    run_and_render(args, JobSpec::Health(HealthJob { window_s }))
}

// ---------------------------------------------------------------------------
// Event rendering (the thin-client half: no coordinator calls anywhere here)
// ---------------------------------------------------------------------------

/// Submit `spec` on a fresh one-slot engine and render its event stream.
fn run_and_render(args: &Args, spec: JobSpec) -> Result<()> {
    let engine = Engine::new(EngineConfig::default());
    let handle = engine.submit(spec);
    let json = args.flag("json");
    let mut header_printed = false;
    let mut failure: Option<String> = None;
    for ev in handle.events() {
        if json {
            println!("{}", ev.to_json().to_string());
            if let Event::Error { message, .. } = &ev {
                failure = Some(message.clone());
            }
            continue;
        }
        match &ev {
            Event::Queued { .. } | Event::Started { .. } => {}
            // Same stream split as the pre-API CLI: bracketed banner /
            // progress lines ("[airbench] ...", "[fleet] ...") go to
            // stderr; result confirmations ("checkpoint written to ...",
            // "fleet log written to ...") go to stdout.
            Event::Log { line, .. } => {
                if line.starts_with('[') {
                    eprintln!("{line}");
                } else {
                    println!("{line}");
                }
            }
            Event::Epoch {
                epoch,
                train_loss,
                train_acc,
                val_acc,
                ..
            } => {
                if !header_printed {
                    logging::print_header(logging::TRAIN_COLUMNS);
                    header_printed = true;
                }
                logging::print_row(
                    logging::TRAIN_COLUMNS,
                    &[
                        ("epoch", epoch.to_string()),
                        ("train_loss", logging::f4(*train_loss as f32)),
                        ("train_acc", logging::f4(*train_acc as f32)),
                        (
                            "val_acc",
                            val_acc.map(|a| logging::f4(a as f32)).unwrap_or_default(),
                        ),
                    ],
                    false,
                );
            }
            Event::Run { run, accuracy, .. } => {
                eprintln!("[fleet] run {run}: {}", pct(*accuracy));
            }
            Event::Result { result, .. } => render_result(result),
            Event::Error {
                message,
                retry_after_ms,
                ..
            } => {
                if let Some(ms) = retry_after_ms {
                    eprintln!("[serve] overloaded — retry in {ms} ms");
                }
                failure = Some(message.clone());
            }
        }
    }
    match failure {
        Some(m) => bail!("{m}"),
        None => Ok(()),
    }
}

fn render_result(result: &JobResult) {
    match result {
        JobResult::Train { result, config, .. } => {
            logging::print_row(
                logging::TRAIN_COLUMNS,
                &[
                    ("epoch", "eval".to_string()),
                    ("tta_val_acc", logging::f4(result.accuracy as f32)),
                    ("total_time_seconds", format!("{:.3}", result.time_seconds)),
                ],
                true,
            );
            println!(
                "final: acc={} (no-TTA {}), epochs={:.2}, steps={}, {:.3}s, {:.2} GFLOP",
                pct(result.accuracy),
                pct(result.accuracy_no_tta),
                result.epochs_run,
                result.steps_run,
                result.time_seconds,
                result.flops as f64 / 1e9,
            );
            if let Some(e) = result.epochs_to_target {
                println!("epochs-to-target({}): {e:.1}", pct(config.target_acc));
            }
        }
        JobResult::Eval {
            accuracy,
            accuracy_no_tta,
            n_test,
            checkpoint,
            ..
        } => {
            println!(
                "checkpoint {}: acc={} (no-TTA {}) on {} test examples",
                checkpoint.display(),
                pct(*accuracy),
                pct(*accuracy_no_tta),
                n_test
            );
        }
        JobResult::Fleet { result, .. } => {
            let s = result.summary();
            println!(
                "fleet n={}: mean={} std={:.3}% ci95=±{:.3}% min={} max={} mean_time={:.2}s",
                s.n,
                pct(s.mean),
                100.0 * s.std,
                100.0 * s.ci95(),
                pct(s.min),
                pct(s.max),
                result.mean_time_seconds(),
            );
        }
        JobResult::Study { result, .. } => {
            println!("study: {} cells x {} seed-paired runs", result.cells.len(), result.runs);
            for cell in &result.cells {
                let s = cell.fleet.summary();
                println!(
                    "  {:<32} mean={} std={:.3}% ci95=±{:.3}% min={} max={}",
                    cell.policy.name(),
                    pct(s.mean),
                    100.0 * s.std,
                    100.0 * s.ci95(),
                    pct(s.min),
                    pct(s.max),
                );
            }
            for i in 0..result.cells.len() {
                for k in (i + 1)..result.cells.len() {
                    if let Ok(c) = result.comparison(i, k) {
                        println!(
                            "  {} vs {}: mean_diff={:+.3}% ci95=±{:.3}% win_frac={:.2}",
                            result.cells[i].policy.name(),
                            result.cells[k].policy.name(),
                            100.0 * c.mean_diff,
                            100.0 * c.ci95_diff,
                            c.win_frac,
                        );
                    }
                }
            }
        }
        JobResult::Bench { report, path } => {
            let row = |name: &str, d: &airbench::bench::Dist, unit: &str| {
                let s = d.summary();
                println!(
                    "  {name:<16} median {:>9.2}{unit}  mean {:>9.2}  std {:>7.2}  min {:>9.2}  max {:>9.2}  (n={})",
                    d.median(),
                    s.mean,
                    s.std,
                    s.min,
                    s.max,
                    s.n
                );
            };
            println!(
                "bench report: backend={} variant={} threads={} kernel={} batch={}",
                report.backend_name,
                report.variant,
                report.threads,
                report.kernel,
                report.batch_train
            );
            row("train_step_ms", &report.step_ms, "ms");
            row("init_ms", &report.init_ms, "ms");
            row("eval_ms", &report.eval_ms, "ms");
            row("run_s", &report.run_s, "s");
            row("run_train_s", &report.run_train_s, "s");
            row("run_eval_s", &report.run_eval_s, "s");
            println!(
                "  step throughput: {:.2} GFLOP/s effective, {:.0} img/s",
                report.train_gflops(),
                report.batch_train as f64 / (report.step_ms.median() * 1e-3).max(1e-12),
            );
            if let Some(p) = path {
                println!("wrote {}", p.display());
            }
        }
        JobResult::FleetBench { report, path } => {
            println!(
                "fleet bench: backend={} variant={} n_runs={} cores={}",
                report.backend_name, report.variant, report.config.n_runs, report.cores
            );
            for l in &report.levels {
                println!(
                    "  parallel {:>2} (x{} kernel threads): {:>7.2}s wall, {:>6.2} runs/s, \
                     speedup {:>5.2}x, mean acc {:.4}, bit-identical: {}",
                    l.parallel,
                    l.kernel_threads,
                    l.wall_s,
                    l.runs_per_s,
                    l.speedup_vs_p1,
                    l.mean_acc,
                    l.bit_identical_to_p1
                );
            }
            if let Some(p) = path {
                println!("wrote {}", p.display());
            }
        }
        JobResult::Save {
            path,
            payload,
            content_hash,
            bytes,
            variant,
        } => {
            println!(
                "saved {variant} model to {} (payload {}, {bytes} bytes, md5 {content_hash})",
                path.display(),
                payload.display(),
            );
        }
        JobResult::Load {
            id,
            content_hash,
            variant,
            params,
            path,
            tensors,
            momenta,
        } => {
            println!(
                "loaded {} as '{id}' ({params} params, variant {variant}, \
                 {tensors} tensors + {momenta} momenta, md5 {content_hash})",
                path.display(),
            );
        }
        JobResult::Predict {
            accuracy,
            accuracy_no_tta,
            n_test,
            model,
            probs_md5,
            ..
        } => {
            println!(
                "predict[{model}]: acc={} (no-TTA {}) on {n_test} test examples (probs md5 {probs_md5})",
                pct(*accuracy),
                pct(*accuracy_no_tta),
            );
        }
        JobResult::PredictOne {
            model,
            index,
            prediction,
            probs,
            probs_md5,
            latency_us,
            ..
        } => {
            let confidence = probs.get(*prediction as usize).copied().unwrap_or(0.0);
            println!(
                "predict_one[{model}] example {index}: class {prediction} \
                 (p={confidence:.4}, {latency_us:.0}us, probs md5 {probs_md5})"
            );
        }
        JobResult::FleetShard {
            shard,
            start,
            accs,
            ..
        } => {
            // Normally consumed by a remote coordinator, not a human; keep
            // the rendering minimal but complete.
            println!(
                "fleet shard {shard}: {} runs starting at global run {start}",
                accs.len()
            );
        }
        JobResult::Health { data } => {
            println!(
                "serve health (last {}s): {} requests, queue depth {}",
                jnum(data, "window_s") as u64,
                jnum(data, "requests") as u64,
                jnum(data, "queue_depth") as u64,
            );
            if let Some(h) = data.opt("latency") {
                println!(
                    "  request_us   n={:<6} mean {:>9.1}  p50 {:>9.1}  \
                     p90 {:>9.1}  p99 {:>9.1}  max {:>9.1}",
                    jnum(h, "n") as u64,
                    jnum(h, "mean_us"),
                    jnum(h, "p50_us"),
                    jnum(h, "p90_us"),
                    jnum(h, "p99_us"),
                    jnum(h, "max_us"),
                );
            }
        }
        JobResult::Metrics { data } => {
            println!(
                "serve metrics: {} requests ({} rejected), {} batches \
                 ({} coalesced, mean batch {:.2}), queue depth {}",
                jnum(data, "requests") as u64,
                jnum(data, "rejected") as u64,
                jnum(data, "batches") as u64,
                jnum(data, "coalesced") as u64,
                jnum(data, "mean_batch"),
                jnum(data, "queue_depth") as u64,
            );
            if let Some(latency) = data.opt("latency") {
                for phase in ["queue_us", "exec_us", "request_us"] {
                    if let Some(h) = latency.opt(phase) {
                        println!(
                            "  {phase:<12} n={:<6} mean {:>9.1}  p50 {:>9.1}  \
                             p90 {:>9.1}  p99 {:>9.1}  max {:>9.1}",
                            jnum(h, "n") as u64,
                            jnum(h, "mean_us"),
                            jnum(h, "p50_us"),
                            jnum(h, "p90_us"),
                            jnum(h, "p99_us"),
                            jnum(h, "max_us"),
                        );
                    }
                }
            }
        }
        JobResult::ServeBench { report, path } => {
            println!(
                "serve bench: backend={} variant={} clients={} x {} requests, cores={}",
                report.backend_name,
                report.variant,
                report.config.clients,
                report.config.requests,
                report.cores
            );
            for l in &report.levels {
                println!(
                    "  max_batch {:>3}: {:>7.2}s wall, {:>8.1} req/s, mean batch {:>5.2}, \
                     p50 {:>7.1}us p99 {:>8.1}us, speedup {:>5.2}x, rejected {}, \
                     bit-identical: {}",
                    l.max_batch,
                    l.wall_s,
                    l.req_per_s,
                    l.mean_batch,
                    l.latency.quantile(0.50),
                    l.latency.quantile(0.99),
                    l.speedup_vs_b1,
                    l.rejected,
                    l.bit_identical_to_b1,
                );
            }
            if let Some(p) = path {
                println!("wrote {}", p.display());
            }
        }
        JobResult::Info { data } => render_info(data),
    }
}

fn jstr<'a>(j: &'a Json, key: &str) -> &'a str {
    j.get(key).and_then(|v| v.as_str()).unwrap_or("?")
}

fn jnum(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0)
}

fn render_info(data: &Json) {
    let manifest = data.get("manifest").and_then(|v| v.as_bool()).unwrap_or(false);
    let variants: &[Json] = data.get("variants").and_then(|v| v.as_arr()).unwrap_or(&[]);
    if let Some(cpu) = data.opt("cpu") {
        let features = cpu
            .get("features")
            .and_then(|f| f.as_arr().map(|a| a.to_vec()))
            .unwrap_or_default()
            .iter()
            .filter_map(|f| f.as_str().ok().map(str::to_string))
            .collect::<Vec<_>>()
            .join(",");
        println!(
            "cpu: {}/{} kernel={} threads={} cores={} features=[{features}]",
            jstr(cpu, "arch"),
            jstr(cpu, "os"),
            jstr(cpu, "kernel"),
            jnum(cpu, "threads") as u64,
            jnum(cpu, "cores") as u64,
        );
    }
    // A single entry carrying "widths" is the detail shape.
    if variants.len() == 1 && variants[0].opt("widths").is_some() {
        let v = &variants[0];
        println!(
            "variant {}: widths={:?} convs_per_block={} residual={}",
            jstr(v, "name"),
            v.get("widths").and_then(|w| w.as_usize_vec()).unwrap_or_default(),
            jnum(v, "convs_per_block") as usize,
            v.get("residual").and_then(|b| b.as_bool()).unwrap_or(false)
        );
        println!(
            "  params={} fwd_mflops/example={:.1}",
            jnum(v, "params") as u64,
            jnum(v, "fwd_mflops_per_example")
        );
        println!("  tensors:");
        for t in v.get("tensors").and_then(|t| t.as_arr()).unwrap_or(&[]) {
            println!(
                "    {:<20} {:?} role={} group={}",
                jstr(t, "name"),
                t.get("shape").and_then(|s| s.as_usize_vec()).unwrap_or_default(),
                jstr(t, "role"),
                jstr(t, "group")
            );
        }
        if let Some(hlo) = data.opt("hlo") {
            for tag in ["train", "eval"] {
                if let Some(m) = hlo.opt(tag) {
                    println!(
                        "{tag} module: {} instructions, {} computations; top ops:",
                        jnum(m, "instructions") as u64,
                        jnum(m, "computations") as u64
                    );
                    for op in m.get("top_ops").and_then(|t| t.as_arr()).unwrap_or(&[]) {
                        println!("    {:<24} {}", jstr(op, "op"), jnum(op, "count") as u64);
                    }
                }
            }
        }
        return;
    }
    let print_rows = |source: &str| {
        for v in variants.iter().filter(|v| jstr(v, "source") == source) {
            println!(
                "  {:<20} params={:<9} batch={}x{} fwd={:.1} MFLOP/example",
                jstr(v, "name"),
                jnum(v, "params") as u64,
                jnum(v, "batch_train") as u64,
                jnum(v, "batch_eval") as u64,
                jnum(v, "fwd_mflops_per_example")
            );
        }
    };
    if manifest {
        println!("AOT variants in {}:", jstr(data, "artifacts_dir"));
        print_rows("manifest");
    } else {
        println!(
            "no AOT artifacts in {} (run `make artifacts`)",
            jstr(data, "artifacts_dir")
        );
    }
    println!("native built-in variants (--backend native):");
    print_rows("native");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_listed_command_dispatches() {
        assert!(!COMMANDS.is_empty());
        for c in COMMANDS {
            let found = find_command(COMMANDS, c.name)
                .unwrap_or_else(|| panic!("listed command '{}' does not dispatch", c.name));
            assert!(
                std::ptr::eq(found, c),
                "dispatch for '{}' resolves to a different entry",
                c.name
            );
            assert!(!c.summary.is_empty(), "'{}' has no usage summary", c.name);
        }
        assert!(find_command(COMMANDS, "frobnicate").is_none());
    }

    #[test]
    fn command_names_are_unique() {
        let mut names: Vec<&str> = COMMANDS.iter().map(|c| c.name).collect();
        names.sort_unstable();
        let before = names.len();
        names.dedup();
        assert_eq!(names.len(), before, "duplicate command names in the table");
    }

    #[test]
    fn flag_spellings_resolve_into_the_config() {
        let args = Args::parse(
            "train --backend native --workers 3 --seed 9 epochs=2"
                .split_whitespace()
                .map(str::to_string),
        )
        .unwrap();
        let cfg = resolved_config(&args).unwrap();
        assert_eq!(cfg.backend, airbench::runtime::BackendKind::Native);
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.epochs, 2.0);
    }

    #[test]
    fn flag_beats_bare_override() {
        let args = Args::parse(
            "train --backend native backend=pjrt"
                .split_whitespace()
                .map(str::to_string),
        )
        .unwrap();
        let cfg = resolved_config(&args).unwrap();
        assert_eq!(cfg.backend, airbench::runtime::BackendKind::Native);
    }
}
