//! HLO-text op census: a small introspection tool over the AOT artifacts.
//!
//! Parses the HLO text the runtime compiles and counts instructions by
//! opcode — used by `airbench info --hlo <variant>` and the L2 section of
//! EXPERIMENTS.md §Perf to verify the lowered module has the expected
//! structure (dots for the kernel matmuls, no stray `while` loops from the
//! interpret-mode grid once the CPU tile profile is active, no
//! custom-calls that the CPU plugin could not run).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

/// Instruction counts by opcode, plus computation count.
#[derive(Clone, Debug, Default)]
pub struct Census {
    /// Instruction count per opcode.
    pub ops: BTreeMap<String, usize>,
    /// Number of HLO computations in the module.
    pub computations: usize,
    /// Total instruction count.
    pub instructions: usize,
}

impl Census {
    /// Count for one opcode (0 when absent).
    pub fn count(&self, op: &str) -> usize {
        self.ops.get(op).copied().unwrap_or(0)
    }

    /// Top-n opcodes by count.
    pub fn top(&self, n: usize) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> = self.ops.iter().map(|(k, c)| (k.clone(), *c)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(n);
        v
    }
}

/// Census of one HLO text module.
///
/// HLO text grammar (the slice we need): computations start with
/// `ENTRY`/`%name (args) -> ty {` or `name {`; instruction lines look like
/// `  %foo.12 = f32[2,3]{1,0} opcode(%bar), attr=...`.
pub fn census_str(text: &str) -> Census {
    let mut c = Census::default();
    for line in text.lines() {
        let t = line.trim_start();
        if t.ends_with('{') && !t.starts_with('%') {
            c.computations += 1;
            continue;
        }
        // instruction: "<lhs> = <shape> <opcode>(...)"
        let Some(eq) = t.find(" = ") else { continue };
        let rhs = &t[eq + 3..];
        // skip the shape token (ends at the first space outside brackets)
        let mut depth = 0usize;
        let mut shape_end = rhs.len();
        for (i, ch) in rhs.char_indices() {
            match ch {
                '[' | '{' | '(' => depth += 1,
                ']' | '}' | ')' => depth = depth.saturating_sub(1),
                ' ' if depth == 0 => {
                    shape_end = i;
                    break;
                }
                _ => {}
            }
        }
        let after = rhs[shape_end..].trim_start();
        let op: String = after
            .chars()
            .take_while(|ch| ch.is_ascii_alphanumeric() || *ch == '-' || *ch == '_')
            .collect();
        if op.is_empty() {
            continue;
        }
        *c.ops.entry(op).or_insert(0) += 1;
        c.instructions += 1;
    }
    c
}

/// Census of an HLO text file.
pub fn census_file(path: &Path) -> Result<Census> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    Ok(census_str(&text))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"HloModule jit_fn

ENTRY %main.10 (p0: f32[2,2], p1: f32[2,2]) -> (f32[2,2]) {
  %p0 = f32[2,2]{1,0} parameter(0)
  %p1 = f32[2,2]{1,0} parameter(1)
  %dot.3 = f32[2,2]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}
  %constant.4 = f32[] constant(2)
  %broadcast.5 = f32[2,2]{1,0} broadcast(%constant.4), dimensions={}
  %add.6 = f32[2,2]{1,0} add(%dot.3, %broadcast.5)
  ROOT %tuple.7 = (f32[2,2]{1,0}) tuple(%add.6)
}
"#;

    #[test]
    fn counts_sample_ops() {
        let c = census_str(SAMPLE);
        assert_eq!(c.count("parameter"), 2);
        assert_eq!(c.count("dot"), 1);
        assert_eq!(c.count("add"), 1);
        assert_eq!(c.count("tuple"), 1);
        assert_eq!(c.computations, 1);
        assert!(c.instructions >= 7);
    }

    #[test]
    fn top_orders_by_count() {
        let c = census_str(SAMPLE);
        assert_eq!(c.top(1)[0].0, "parameter");
    }

    #[test]
    fn real_artifacts_have_expected_structure() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let f = dir.join("bench_train.hlo.txt");
        if !f.exists() {
            return;
        }
        let c = census_file(&f).unwrap();
        // the Pallas matmuls lower to dots/fusions...
        assert!(c.count("dot") + c.count("fusion") > 0, "{:?}", c.top(10));
        // ...and the CPU tile profile must not leave grid while-loops
        // (§Perf iteration 2) or unrunnable custom-calls.
        assert_eq!(c.count("custom-call"), 0, "{:?}", c.top(20));
    }

    #[test]
    fn empty_and_garbage_are_fine() {
        assert_eq!(census_str("").instructions, 0);
        let c = census_str("not hlo at all\nstill not = hlo\n");
        assert!(c.instructions <= 1);
    }
}
