//! Column-aligned training logger, replicating the paper's
//! `print_columns` / `print_training_details` output format (Listing 4).

/// The paper's logging columns.
pub const TRAIN_COLUMNS: &[&str] = &[
    "run   ",
    "epoch",
    "train_loss",
    "train_acc",
    "val_acc",
    "tta_val_acc",
    "total_time_seconds",
];

/// One formatted cell: right-justified into its column width.
fn cell(text: &str, width: usize) -> String {
    format!("{text:>width$}")
}

/// Render one row (`| a | b |`) given `(column, value)` pairs; columns
/// missing a value render empty, like the paper's logger.
pub fn format_row(columns: &[&str], values: &[(&str, String)]) -> String {
    let mut out = String::new();
    for col in columns {
        let v = values
            .iter()
            .find(|(k, _)| *k == col.trim())
            .map(|(_, v)| v.clone())
            .unwrap_or_default();
        out.push_str("| ");
        out.push_str(&cell(&v, col.len()));
        out.push(' ');
    }
    out.push('|');
    out
}

/// Print the header (with rules above and below, like the paper).
pub fn print_header(columns: &[&str]) {
    let head = format_row(columns, &columns.iter().map(|c| (c.trim(), c.trim().to_string())).collect::<Vec<_>>());
    println!("{}", "-".repeat(head.len()));
    println!("{head}");
    println!("{}", "-".repeat(head.len()));
}

/// Print a data row; `is_final` adds the closing rule.
pub fn print_row(columns: &[&str], values: &[(&str, String)], is_final: bool) {
    let row = format_row(columns, values);
    println!("{row}");
    if is_final {
        println!("{}", "-".repeat(row.len()));
    }
}

/// Format a float the way the paper does (`{:0.4f}`).
pub fn f4(x: f32) -> String {
    format!("{x:.4}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_right_justifies() {
        let cols = ["abcdef", "xy"];
        let row = format_row(&cols, &[("abcdef", "7".into()), ("xy", "q".into())]);
        assert_eq!(row, "|      7 |  q |");
    }

    #[test]
    fn missing_values_render_empty() {
        let cols = ["abc"];
        let row = format_row(&cols, &[]);
        assert_eq!(row, "|     |");
    }

    #[test]
    fn f4_format() {
        assert_eq!(f4(0.94012), "0.9401");
    }
}
