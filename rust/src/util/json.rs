//! Minimal JSON reader/writer (serde is not vendored on this image).
//!
//! Covers the full JSON grammar we produce/consume: the AOT manifest
//! written by `python/compile/aot.py`, run configs, and experiment logs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects keep sorted key order (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (integers round-trip below 2^53).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with sorted keys.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----- typed accessors -------------------------------------------------

    /// Required object key (error when absent or not an object).
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking for '{key}')"),
        }
    }

    /// Optional object key (`None` when absent or not an object).
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The value as f64 (error for non-numbers).
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// The value as usize (truncating; error for non-numbers).
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// The value as a string slice.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// The value as a bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    /// The value as an array slice.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// The value as an object map.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// `[1,2,3]` -> `vec![1,2,3]` of usize.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ----- construction helpers -------------------------------------------

    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a number.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Build a string.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ----- serialization ---------------------------------------------------

    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Indented serialization (2 spaces per level, one key or element per
    /// line) — used for committed artifacts like `BENCH_*.json` so git
    /// diffs stay line-oriented.
    pub fn to_pretty_string(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (key, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    Json::Str(key.clone()).write(out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            other => other.write(out),
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{text}' at byte {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_scalars() {
        for src in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" A");
        // writer round-trips the escapes
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"caf\u{e9} \u{4e2d}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café 中");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn usize_vec() {
        let v = parse("[3, 4, 5]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![3, 4, 5]);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn pretty_round_trips_and_indents() {
        let v = parse(r#"{"a": [1, 2], "b": {"c": "x"}, "d": [], "e": {}}"#).unwrap();
        let pretty = v.to_pretty_string();
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains("\"a\": [\n    1,\n    2\n  ]"), "{pretty}");
        assert!(pretty.contains("\"d\": []"));
        assert!(pretty.contains("\"e\": {}"));
        assert!(pretty.ends_with('\n'));
    }

    #[test]
    fn property_random_tree_round_trips() {
        use crate::rng::Rng;
        fn gen(rng: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.coin(0.5)),
                2 => Json::Num((rng.normal() * 100.0) as f64),
                3 => {
                    let n = rng.below(8);
                    Json::Str((0..n).map(|_| {
                        char::from_u32(32 + rng.below(95) as u32).unwrap()
                    }).collect())
                }
                4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Json::Obj((0..rng.below(4)).map(|i| {
                    (format!("k{i}"), gen(rng, depth - 1))
                }).collect()),
            }
        }
        crate::util::proptest::check(
            "json_round_trip",
            100,
            |rng| gen(rng, 3),
            |v| parse(&v.to_string()).map(|p| p == *v).unwrap_or(false),
        );
    }

    #[test]
    fn parser_never_panics_on_garbage() {
        use crate::rng::Rng;
        crate::util::proptest::check(
            "json_no_panic",
            200,
            |rng: &mut Rng| {
                let n = 1 + rng.below(30);
                let bytes: Vec<u8> = (0..n).map(|_| 32 + (rng.below(95)) as u8).collect();
                String::from_utf8(bytes).unwrap()
            },
            |s| {
                let _ = parse(s); // must return, never panic
                true
            },
        );
    }

    #[test]
    fn real_manifest_snippet() {
        let src = r#"{"format": 1, "variants": {"bench": {"batch_train": 64,
            "tensors": [{"name": "whiten_b", "shape": [24], "role": "trainable"}]}}}"#;
        let v = parse(src).unwrap();
        let t = v
            .get("variants").unwrap()
            .get("bench").unwrap()
            .get("tensors").unwrap();
        assert_eq!(
            t.as_arr().unwrap()[0].get("shape").unwrap().as_usize_vec().unwrap(),
            vec![24]
        );
    }
}
