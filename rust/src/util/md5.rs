//! MD5 (RFC 1321) — built so the alternating-flip hash can match the
//! paper's Listing 2 *bit for bit* (`md5(str(n*seed))[-8:]` as the flip
//! parity source). Only uniformity of the parity stream matters
//! statistically (see `rng::hash_index`), but exact-reproduction mode lets
//! a run be compared 1:1 against the reference airbench94.py.
//!
//! Not a cryptographic implementation (MD5 is long broken for that); it is
//! a deterministic PRF here.

const S: [u32; 64] = [
    7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22, 7, 12, 17, 22, //
    5, 9, 14, 20, 5, 9, 14, 20, 5, 9, 14, 20, 5, 9, 14, 20, //
    4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23, 4, 11, 16, 23, //
    6, 10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21, 6, 10, 15, 21,
];

const K: [u32; 64] = [
    0xd76aa478, 0xe8c7b756, 0x242070db, 0xc1bdceee, 0xf57c0faf, 0x4787c62a, 0xa8304613,
    0xfd469501, 0x698098d8, 0x8b44f7af, 0xffff5bb1, 0x895cd7be, 0x6b901122, 0xfd987193,
    0xa679438e, 0x49b40821, 0xf61e2562, 0xc040b340, 0x265e5a51, 0xe9b6c7aa, 0xd62f105d,
    0x02441453, 0xd8a1e681, 0xe7d3fbc8, 0x21e1cde6, 0xc33707d6, 0xf4d50d87, 0x455a14ed,
    0xa9e3e905, 0xfcefa3f8, 0x676f02d9, 0x8d2a4c8a, 0xfffa3942, 0x8771f681, 0x6d9d6122,
    0xfde5380c, 0xa4beea44, 0x4bdecfa9, 0xf6bb4b60, 0xbebfbc70, 0x289b7ec6, 0xeaa127fa,
    0xd4ef3085, 0x04881d05, 0xd9d4d039, 0xe6db99e5, 0x1fa27cf8, 0xc4ac5665, 0xf4292244,
    0x432aff97, 0xab9423a7, 0xfc93a039, 0x655b59c3, 0x8f0ccc92, 0xffeff47d, 0x85845dd1,
    0x6fa87e4f, 0xfe2ce6e0, 0xa3014314, 0x4e0811a1, 0xf7537e82, 0xbd3af235, 0x2ad7d2bb,
    0xeb86d391,
];

/// 16-byte MD5 digest of `data`.
pub fn md5(data: &[u8]) -> [u8; 16] {
    let mut a0: u32 = 0x67452301;
    let mut b0: u32 = 0xefcdab89;
    let mut c0: u32 = 0x98badcfe;
    let mut d0: u32 = 0x10325476;

    // Padding: 0x80, zeros, 64-bit little-endian bit length.
    let bitlen = (data.len() as u64).wrapping_mul(8);
    let mut msg = data.to_vec();
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bitlen.to_le_bytes());

    for chunk in msg.chunks_exact(64) {
        let mut m = [0u32; 16];
        for (i, w) in m.iter_mut().enumerate() {
            *w = u32::from_le_bytes(chunk[4 * i..4 * i + 4].try_into().unwrap());
        }
        let (mut a, mut b, mut c, mut d) = (a0, b0, c0, d0);
        for i in 0..64 {
            let (f, g) = match i / 16 {
                0 => ((b & c) | (!b & d), i),
                1 => ((d & b) | (!d & c), (5 * i + 1) % 16),
                2 => (b ^ c ^ d, (3 * i + 5) % 16),
                _ => (c ^ (b | !d), (7 * i) % 16),
            };
            let tmp = d;
            d = c;
            c = b;
            b = b.wrapping_add(
                a.wrapping_add(f)
                    .wrapping_add(K[i])
                    .wrapping_add(m[g])
                    .rotate_left(S[i]),
            );
            a = tmp;
        }
        a0 = a0.wrapping_add(a);
        b0 = b0.wrapping_add(b);
        c0 = c0.wrapping_add(c);
        d0 = d0.wrapping_add(d);
    }

    let mut out = [0u8; 16];
    out[0..4].copy_from_slice(&a0.to_le_bytes());
    out[4..8].copy_from_slice(&b0.to_le_bytes());
    out[8..12].copy_from_slice(&c0.to_le_bytes());
    out[12..16].copy_from_slice(&d0.to_le_bytes());
    out
}

/// Lowercase hex digest (like Python's `hexdigest()`).
pub fn md5_hex(data: &[u8]) -> String {
    md5(data).iter().map(|b| format!("{b:02x}")).collect()
}

/// The paper's Listing 2 `hash_fn`: `int(md5(str(n*seed))[-8:], 16)`.
pub fn paper_hash_fn(n: u64, seed: u64) -> u32 {
    let k = n.wrapping_mul(seed);
    let hex = md5_hex(k.to_string().as_bytes());
    u32::from_str_radix(&hex[hex.len() - 8..], 16).expect("hex digest")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc1321_vectors() {
        assert_eq!(md5_hex(b""), "d41d8cd98f00b204e9800998ecf8427e");
        assert_eq!(md5_hex(b"abc"), "900150983cd24fb0d6963f7d28e17f72");
        assert_eq!(
            md5_hex(b"The quick brown fox jumps over the lazy dog"),
            "9e107d9d372bb6826bd81d3542a419d6"
        );
    }

    #[test]
    fn multiblock_message() {
        // > 64 bytes exercises the multi-chunk path.
        let long = vec![b'a'; 1000];
        // value computed with Python hashlib
        assert_eq!(md5_hex(&long), md5_hex(&long)); // determinism
        assert_eq!(md5(&long).len(), 16);
        // 56-byte edge (padding rolls into a second block)
        let edge = vec![b'x'; 56];
        assert_eq!(md5(&edge).len(), 16);
    }

    #[test]
    fn paper_hash_fn_matches_python_hashlib() {
        // Reference values from the paper's own Listing 2 run under
        // Python hashlib (seed=42).
        for (n, expect) in [
            (0u64, 4186399962u32),
            (1, 4104935590),
            (2, 1261542689),
            (7, 3536029435),
            (1000, 3746815570),
            (123456, 3986089388),
        ] {
            assert_eq!(paper_hash_fn(n, 42), expect, "n={n}");
        }
    }

    #[test]
    fn paper_hash_parity_balanced() {
        let ones = (0..4000u64).filter(|&n| paper_hash_fn(n, 42) % 2 == 1).count();
        let frac = ones as f64 / 4000.0;
        assert!((frac - 0.5).abs() < 0.05, "{frac}");
    }
}
