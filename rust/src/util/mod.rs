//! Shared substrates: JSON, the paper-style column logger, a bench harness
//! (criterion is not vendored on this image), and a small property-testing
//! harness (proptest is not vendored either).

pub mod benchmark;
pub mod hlo_census;
pub mod md5;
pub mod json;
pub mod logging;
pub mod proptest;
