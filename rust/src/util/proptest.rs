//! Property-testing harness (proptest is not vendored on this image).
//!
//! [`check`] runs a property over `cases` randomized inputs drawn from a
//! generator closure; on failure it performs greedy shrinking via the
//! generator's seed stream and reports the minimal failing seed so the case
//! is reproducible (`PROP_SEED=<n>`).

use crate::rng::Rng;

/// Run `prop(gen(rng))` for `cases` random cases. Panics with the failing
/// seed on the first violated property.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xDEC0DEu64);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (PROP_SEED={seed}):\n{input:#?}"
            );
        }
    }
}

/// Case-count knob: `PROP_CASES=<n>` overrides `default` so CI can dial a
/// property suite up (soak) or down (smoke) without a rebuild. Values that
/// fail to parse, or parse to zero, fall back to `default`.
pub fn cases_from_env(default: usize) -> usize {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

/// Like [`check`] but the property returns `Result`, so `?` works inside.
pub fn check_result<T: std::fmt::Debug, E: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), E>,
) {
    check(name, cases, &mut gen, |input| match prop(input) {
        Ok(()) => true,
        Err(e) => {
            eprintln!("property '{name}' error: {e:?}");
            false
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("sum_commutes", 50, |r| (r.below(100), r.below(100)), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_false' failed")]
    fn failing_property_panics_with_seed() {
        check("always_false", 5, |r| r.below(10), |_| false);
    }

    #[test]
    fn cases_from_env_falls_back_to_the_default() {
        // The suite does not set PROP_CASES, so the default must win; a
        // zero or garbage value would also land here by the filter.
        if std::env::var("PROP_CASES").is_err() {
            assert_eq!(cases_from_env(7), 7);
        }
    }
}
