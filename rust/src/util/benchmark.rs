//! Micro/endto-end benchmark harness (criterion is not vendored here).
//!
//! `cargo bench` targets use [`Bench`] to time closures with warmup,
//! report mean/median/min and throughput, and print table rows that mirror
//! the paper's evaluation tables.

use std::time::{Duration, Instant};

/// Timing summary of one benchmark case.
#[derive(Clone, Debug)]
pub struct Sample {
    /// Case label (printed in the report row).
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Median per-iteration time.
    pub median: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Slowest iteration.
    pub max: Duration,
}

impl Sample {
    /// Mean per-iteration time in seconds.
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// items/second given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }
}

/// A tiny criterion-alike: fixed warmup iterations then timed iterations.
pub struct Bench {
    /// Untimed warmup iterations before measurement.
    pub warmup_iters: usize,
    /// Timed iterations.
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 2,
            iters: 10,
        }
    }
}

impl Bench {
    /// Build a bench with explicit warmup / timed iteration counts.
    pub fn new(warmup_iters: usize, iters: usize) -> Bench {
        Bench {
            warmup_iters,
            iters,
        }
    }

    /// Time `f`, returning the per-iteration stats. The closure's return
    /// value is passed through `std::hint::black_box` to defeat DCE.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Sample {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed());
        }
        times.sort();
        let total: Duration = times.iter().sum();
        let s = Sample {
            name: name.to_string(),
            iters: self.iters,
            mean: total / self.iters as u32,
            median: times[self.iters / 2],
            min: times[0],
            max: times[self.iters - 1],
        };
        println!(
            "{:<40} mean {:>10.3?}  median {:>10.3?}  min {:>10.3?}  (n={})",
            s.name, s.mean, s.median, s.min, s.iters
        );
        s
    }
}

/// Pretty table printer for the paper-reproduction benches: fixed-width
/// columns, header rule, one row per call.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    /// Print the header row and rule; returns the column layout.
    pub fn new(headers: &[&str]) -> Table {
        let widths: Vec<usize> = headers.iter().map(|h| h.len().max(10)).collect();
        let t = Table { widths };
        t.row(headers);
        println!("{}", "-".repeat(t.widths.iter().sum::<usize>() + 3 * t.widths.len() + 1));
        t
    }

    /// Print one data row under the header.
    pub fn row(&self, cells: &[&str]) {
        let mut line = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            let w = self.widths.get(i).copied().unwrap_or(10);
            line.push_str(&format!(" {c:>w$} |"));
        }
        println!("{line}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0usize;
        let b = Bench::new(1, 5);
        let s = b.run("count", || {
            n += 1;
            n
        });
        assert_eq!(n, 6); // 1 warmup + 5 timed
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn throughput_math() {
        let s = Sample {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_millis(100),
            median: Duration::from_millis(100),
            min: Duration::from_millis(100),
            max: Duration::from_millis(100),
        };
        assert!((s.throughput(50.0) - 500.0).abs() < 1e-9);
    }
}
