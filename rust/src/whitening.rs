//! Frozen patch-whitening initialization (paper §3.2).
//!
//! The first layer is a 2x2 conv whose first 12 filters are the
//! eigenvectors of the covariance matrix of 2x2 training patches, scaled by
//! `1/sqrt(eigenvalue + eps)` so outputs have identity covariance; the
//! second 12 are their negations (information is preserved through the
//! GELU). The paper computes this from the first 5000 training images and
//! freezes the weights.
//!
//! Substrate built here: a cyclic Jacobi symmetric eigensolver (no LAPACK
//! on this image) — for the 12x12 patch covariance it converges to machine
//! precision in a handful of sweeps.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// Eigendecomposition of a symmetric matrix by the cyclic Jacobi method.
/// Returns (eigenvalues, eigenvectors) with eigenvectors in ROWS, sorted by
/// DESCENDING eigenvalue (the paper flips eigh's ascending order).
pub fn symmetric_eigh(a: &[f64], n: usize) -> Result<(Vec<f64>, Vec<f64>)> {
    if a.len() != n * n {
        bail!("matrix must be {n}x{n}, got {} elements", a.len());
    }
    let mut m = a.to_vec();
    // v starts as identity; accumulates rotations as COLUMNS = eigenvectors.
    let mut v = vec![0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let idx = |r: usize, c: usize| r * n + c;
    for _sweep in 0..100 {
        let mut off = 0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m[idx(p, q)] * m[idx(p, q)];
            }
        }
        if off < 1e-24 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[idx(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[idx(p, p)];
                let aqq = m[idx(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q of m.
                for k in 0..n {
                    let mkp = m[idx(k, p)];
                    let mkq = m[idx(k, q)];
                    m[idx(k, p)] = c * mkp - s * mkq;
                    m[idx(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[idx(p, k)];
                    let mqk = m[idx(q, k)];
                    m[idx(p, k)] = c * mpk - s * mqk;
                    m[idx(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[idx(k, p)];
                    let vkq = v[idx(k, q)];
                    v[idx(k, p)] = c * vkp - s * vkq;
                    v[idx(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    // Extract (eigenvalue, eigenvector-column) pairs, sort descending.
    let mut pairs: Vec<(f64, Vec<f64>)> = (0..n)
        .map(|j| {
            let lam = m[idx(j, j)];
            let vec: Vec<f64> = (0..n).map(|i| v[idx(i, j)]).collect();
            (lam, vec)
        })
        .collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let eigenvalues: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let mut eigenvectors = vec![0f64; n * n];
    for (r, p) in pairs.iter().enumerate() {
        eigenvectors[r * n..(r + 1) * n].copy_from_slice(&p.1);
    }
    Ok((eigenvalues, eigenvectors))
}

/// Covariance matrix (d x d, d = c*k*k) of all k x k patches (stride 1)
/// across `images` — the paper's `(patches_flat.T @ patches_flat) / n`
/// (uncentered second moment, exactly as Listing 4 computes it).
pub fn patch_covariance(images: &Tensor, k: usize) -> Vec<f64> {
    let (n, c, h, w) = images.dims4();
    let d = c * k * k;
    let mut cov = vec![0f64; d * d];
    let mut patch = vec![0f64; d];
    let mut count = 0f64;
    for ni in 0..n {
        let img = images.image(ni);
        for y in 0..=(h - k) {
            for x in 0..=(w - k) {
                let mut t = 0;
                for ci in 0..c {
                    for dy in 0..k {
                        let row = (ci * h + y + dy) * w + x;
                        for dx in 0..k {
                            patch[t] = img[row + dx] as f64;
                            t += 1;
                        }
                    }
                }
                count += 1.0;
                // accumulate upper triangle
                for i in 0..d {
                    let pi = patch[i];
                    for j in i..d {
                        cov[i * d + j] += pi * patch[j];
                    }
                }
            }
        }
    }
    for i in 0..d {
        for j in i..d {
            let v = cov[i * d + j] / count;
            cov[i * d + j] = v;
            cov[j * d + i] = v;
        }
    }
    cov
}

/// Compute the frozen whitening conv weights (paper §3.2 / Listing 4
/// `init_whitening_conv`): rows are `eigvec / sqrt(eigval + eps)` followed
/// by their negations. Returns a `(2d, c, k, k)` tensor, d = c*k*k.
///
/// The paper notes reducing `eps` (vs tysam-code's 1e-2) gives a small
/// boost; its Listing 4 uses 5e-4, our default too.
pub fn whitening_weights(images: &Tensor, k: usize, eps: f64) -> Result<Tensor> {
    let (_, c, _, _) = images.dims4();
    let d = c * k * k;
    let cov = patch_covariance(images, k);
    let (eigenvalues, eigenvectors) = symmetric_eigh(&cov, d)?;
    let mut w = vec![0f32; 2 * d * d];
    for r in 0..d {
        let scale = 1.0 / (eigenvalues[r].max(0.0) + eps).sqrt();
        for j in 0..d {
            let val = (eigenvectors[r * d + j] * scale) as f32;
            w[r * d + j] = val; // filter r
            w[(d + r) * d + j] = -val; // negated twin (filter d + r)
        }
    }
    Tensor::from_vec(&[2 * d, c, k, k], w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::util::proptest;

    fn matmul(a: &[f64], b: &[f64], n: usize) -> Vec<f64> {
        let mut out = vec![0f64; n * n];
        for i in 0..n {
            for kk in 0..n {
                let aik = a[i * n + kk];
                for j in 0..n {
                    out[i * n + j] += aik * b[kk * n + j];
                }
            }
        }
        out
    }

    #[test]
    fn eigh_identity() {
        let n = 4;
        let mut a = vec![0f64; 16];
        for i in 0..4 {
            a[i * n + i] = 1.0;
        }
        let (vals, _) = symmetric_eigh(&a, n).unwrap();
        for v in vals {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn eigh_known_2x2() {
        // [[2, 1], [1, 2]] has eigenvalues 3 and 1.
        let a = vec![2.0, 1.0, 1.0, 2.0];
        let (vals, vecs) = symmetric_eigh(&a, 2).unwrap();
        assert!((vals[0] - 3.0).abs() < 1e-12);
        assert!((vals[1] - 1.0).abs() < 1e-12);
        // first eigenvector ∝ (1, 1)
        assert!((vecs[0].abs() - vecs[1].abs()).abs() < 1e-9);
    }

    #[test]
    fn eigh_reconstructs_matrix() {
        proptest::check(
            "eigh_reconstruction",
            20,
            |r| {
                let n = 3 + r.below(8);
                // random symmetric
                let mut a = vec![0f64; n * n];
                for i in 0..n {
                    for j in i..n {
                        let v = (r.uniform() * 2.0 - 1.0) as f64;
                        a[i * n + j] = v;
                        a[j * n + i] = v;
                    }
                }
                (n, a)
            },
            |(n, a)| {
                let n = *n;
                let (vals, vecs) = symmetric_eigh(a, n).unwrap();
                // Reconstruct V^T diag(vals) V where rows of `vecs` are
                // eigenvectors: A = sum_r lam_r v_r v_r^T.
                let mut recon = vec![0f64; n * n];
                for r in 0..n {
                    for i in 0..n {
                        for j in 0..n {
                            recon[i * n + j] +=
                                vals[r] * vecs[r * n + i] * vecs[r * n + j];
                        }
                    }
                }
                recon
                    .iter()
                    .zip(a.iter())
                    .all(|(x, y)| (x - y).abs() < 1e-8)
            },
        );
    }

    #[test]
    fn eigh_eigenvectors_orthonormal() {
        let mut r = Rng::new(3);
        let n = 12;
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            for j in i..n {
                let v = r.normal() as f64;
                a[i * n + j] = v;
                a[j * n + i] = v;
            }
        }
        let (_, vecs) = symmetric_eigh(&a, n).unwrap();
        // rows orthonormal: vecs @ vecs^T = I
        let mut vt = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                vt[j * n + i] = vecs[i * n + j];
            }
        }
        let prod = matmul(&vecs, &vt, n);
        for i in 0..n {
            for j in 0..n {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (prod[i * n + j] - expect).abs() < 1e-9,
                    "({i},{j}) = {}",
                    prod[i * n + j]
                );
            }
        }
    }

    #[test]
    fn eigh_rejects_bad_size() {
        assert!(symmetric_eigh(&[1.0; 5], 2).is_err());
    }

    #[test]
    fn patch_covariance_of_constant_images() {
        // Constant image c: every patch is (c..c), cov = c^2 * ones.
        let images = Tensor::full(&[2, 1, 4, 4], 2.0);
        let cov = patch_covariance(&images, 2);
        for v in &cov {
            assert!((v - 4.0).abs() < 1e-9);
        }
    }

    #[test]
    fn patch_covariance_is_symmetric_psd() {
        let mut r = Rng::new(9);
        let mut images = Tensor::zeros(&[4, 3, 8, 8]);
        for v in images.data_mut() {
            *v = r.normal();
        }
        let d = 12;
        let cov = patch_covariance(&images, 2);
        for i in 0..d {
            for j in 0..d {
                assert!((cov[i * d + j] - cov[j * d + i]).abs() < 1e-9);
            }
        }
        let (vals, _) = symmetric_eigh(&cov, d).unwrap();
        for v in vals {
            assert!(v > -1e-9, "negative eigenvalue {v}");
        }
    }

    #[test]
    fn whitening_weights_shape_and_negation() {
        let mut r = Rng::new(5);
        let mut images = Tensor::zeros(&[8, 3, 8, 8]);
        for v in images.data_mut() {
            *v = r.normal();
        }
        let w = whitening_weights(&images, 2, 5e-4).unwrap();
        assert_eq!(w.shape(), &[24, 3, 2, 2]);
        // second half is the negation of the first (paper §3.2)
        let d = 12;
        let flat = w.data();
        for i in 0..d * d {
            assert_eq!(flat[i], -flat[d * d + i]);
        }
    }

    #[test]
    fn whitening_whitens() {
        // After the whitening transform, patch outputs should have ~identity
        // covariance (that is the definition used by the paper).
        let mut r = Rng::new(6);
        let mut images = Tensor::zeros(&[16, 3, 10, 10]);
        for v in images.data_mut() {
            *v = r.normal() * 0.5 + 0.1;
        }
        let k = 2;
        let d = 12;
        let w = whitening_weights(&images, k, 1e-8).unwrap();
        let wf = w.data();
        // Project every patch through the first d filters and accumulate
        // output covariance.
        let (n, c, h, wd) = images.dims4();
        let mut cov = vec![0f64; d * d];
        let mut cnt = 0f64;
        let mut patch = vec![0f64; d];
        let mut out = vec![0f64; d];
        for ni in 0..n {
            let img = images.image(ni);
            for y in 0..=(h - k) {
                for x in 0..=(wd - k) {
                    let mut t = 0;
                    for ci in 0..c {
                        for dy in 0..k {
                            for dx in 0..k {
                                patch[t] = img[(ci * h + y + dy) * wd + x + dx] as f64;
                                t += 1;
                            }
                        }
                    }
                    for f in 0..d {
                        out[f] = (0..d).map(|j| wf[f * d + j] as f64 * patch[j]).sum();
                    }
                    for i in 0..d {
                        for j in 0..d {
                            cov[i * d + j] += out[i] * out[j];
                        }
                    }
                    cnt += 1.0;
                }
            }
        }
        for i in 0..d {
            for j in 0..d {
                let v = cov[i * d + j] / cnt;
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (v - expect).abs() < 0.05,
                    "output covariance ({i},{j}) = {v}"
                );
            }
        }
    }

    #[test]
    fn eps_regularizes_singular_covariance() {
        // Degenerate data (all images identical) yields singular covariance;
        // eps must keep the weights finite.
        let images = Tensor::full(&[4, 3, 6, 6], 0.7);
        let w = whitening_weights(&images, 2, 5e-4).unwrap();
        assert!(w.data().iter().all(|v| v.is_finite()));
        let maxabs = w.data().iter().fold(0f32, |m, v| m.max(v.abs()));
        assert!(maxabs < 100.0, "weights blew up: {maxabs}");
    }
}
