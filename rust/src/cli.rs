//! Minimal CLI argument parser (clap is not vendored on this image).
//!
//! Grammar: `airbench <command> [--flag] [--key value] [--key=value]
//! [key=value ...]`. Bare `key=value` positionals are config overrides
//! passed to `TrainConfig::set`, mirroring the launcher style of large
//! training frameworks.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// One entry of the CLI's command table. `usage()` text and `main()`
/// dispatch are both generated from the same table row, so the help
/// output and the dispatcher cannot diverge (the dispatch test in
/// `main.rs` pins it).
pub struct Command {
    /// Subcommand name (`train`, `serve`, ...).
    pub name: &'static str,
    /// One-line summary printed by the generated usage text.
    pub summary: &'static str,
    /// Handler the dispatcher invokes.
    pub run: fn(&Args) -> Result<()>,
}

/// Look up `name` in a command table — the single dispatch path.
pub fn find_command<'a>(table: &'a [Command], name: &str) -> Option<&'a Command> {
    table.iter().find(|c| c.name == name)
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (subcommand), if any.
    pub command: Option<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `key=value` config overrides, in order.
    pub overrides: Vec<(String, String)>,
    /// Bare positionals that are not overrides.
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(flag) = tok.strip_prefix("--") {
                if flag.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = flag.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` if the next token isn't another flag,
                    // else a boolean `--key`.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") && !next.contains('=') => {
                            let v = it.next().unwrap();
                            args.options.insert(flag.to_string(), v);
                        }
                        _ => {
                            args.options.insert(flag.to_string(), "true".to_string());
                        }
                    }
                }
            } else if let Some((k, v)) = tok.split_once('=') {
                args.overrides.push((k.to_string(), v.to_string()));
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse the process's command line (argv[0] excluded).
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// Option value with default.
    pub fn opt(&self, key: &str, default: &str) -> String {
        self.options
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Typed option accessors.
    pub fn opt_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    /// `--key` as f64, with default.
    pub fn opt_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    /// `--key` as u64, with default.
    pub fn opt_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    /// `--key` as a comma-separated list of integers (e.g. the fleet
    /// bench's `--parallel-levels 1,2,4`), with default.
    pub fn opt_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.options.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse::<usize>())
                .collect::<Result<Vec<_>, _>>()
                .map_err(|_| {
                    anyhow::anyhow!("--{key} expects comma-separated integers, got '{v}'")
                }),
        }
    }

    /// Boolean `--key` (present without a value, or `=true`/`=1`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.options.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --runs 5 --variant=bench epochs=3.5 flip=random");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.opt("runs", "1"), "5");
        assert_eq!(a.opt("variant", "x"), "bench");
        assert_eq!(
            a.overrides,
            vec![
                ("epochs".to_string(), "3.5".to_string()),
                ("flip".to_string(), "random".to_string())
            ]
        );
    }

    #[test]
    fn boolean_flags() {
        let a = parse("bench --quiet --n 3");
        assert!(a.flag("quiet"));
        assert!(!a.flag("verbose"));
        assert_eq!(a.opt_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse("run --fast --seed 7");
        assert!(a.flag("fast"));
        assert_eq!(a.opt_u64("seed", 0).unwrap(), 7);
    }

    #[test]
    fn typed_accessor_errors() {
        let a = parse("x --n abc");
        assert!(a.opt_usize("n", 0).is_err());
        assert_eq!(a.opt_usize("m", 9).unwrap(), 9);
    }

    #[test]
    fn usize_list_parses_and_defaults() {
        let a = parse("bench --parallel-levels 1,2,4");
        assert_eq!(a.opt_usize_list("parallel-levels", &[1]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.opt_usize_list("absent", &[3, 5]).unwrap(), vec![3, 5]);
        let b = parse("bench --parallel-levels 1,x");
        assert!(b.opt_usize_list("parallel-levels", &[1]).is_err());
    }

    #[test]
    fn positionals_after_command() {
        let a = parse("report out.json extra");
        assert_eq!(a.command.as_deref(), Some("report"));
        assert_eq!(a.positionals, vec!["out.json", "extra"]);
    }

    #[test]
    fn parser_never_panics_on_random_tokens() {
        use crate::rng::Rng;
        crate::util::proptest::check(
            "cli_no_panic",
            200,
            |rng: &mut Rng| {
                let n = rng.below(6);
                (0..n)
                    .map(|_| {
                        let len = 1 + rng.below(8);
                        (0..len)
                            .map(|_| char::from_u32(33 + rng.below(90) as u32).unwrap())
                            .collect::<String>()
                    })
                    .collect::<Vec<String>>()
            },
            |tokens| Args::parse(tokens.clone()).map(|_| true).unwrap_or(true),
        );
    }

    #[test]
    fn override_with_equals_value_containing_path() {
        let a = parse("train --config configs/a.json");
        assert_eq!(a.opt("config", ""), "configs/a.json");
    }
}
