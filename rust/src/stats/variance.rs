//! Run-to-run variance decomposition (paper §5.3; Jordan 2023).
//!
//! The observed between-run variance of *test-set accuracy* conflates two
//! sources: genuine distribution-wise variance (runs differ in true
//! accuracy) and finite-test-set binomial noise. Jordan 2023's estimator
//! subtracts the expected binomial term:
//!
//! `sigma^2_dist = max(0, sigma^2_test - mean_i[ p_i (1 - p_i) / n_test ])`
//!
//! The paper's §5.3 finding is `sigma_dist <= sigma_test / 5` for all
//! airbench settings; Table 4 reports both columns plus CACE.

use crate::stats::basic::Summary;

/// Decomposition of run-to-run accuracy variance.
#[derive(Clone, Copy, Debug)]
pub struct VarianceDecomposition {
    /// Between-run stddev of test-set accuracy.
    pub test_set_std: f64,
    /// Estimated distribution-wise stddev (binomial noise removed).
    pub dist_wise_std: f64,
    /// Mean accuracy across runs.
    pub mean: f64,
}

/// Estimate the decomposition from per-run accuracies on a test set of
/// `n_test` examples.
pub fn decompose_variance(accuracies: &[f64], n_test: usize) -> VarianceDecomposition {
    let s = Summary::of(accuracies);
    let binom: f64 = accuracies
        .iter()
        .map(|&p| p * (1.0 - p) / n_test as f64)
        .sum::<f64>()
        / accuracies.len().max(1) as f64;
    let dist_var = (s.std * s.std - binom).max(0.0);
    VarianceDecomposition {
        test_set_std: s.std,
        dist_wise_std: dist_var.sqrt(),
        mean: s.mean,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Simulate runs whose true accuracy has stddev `sigma_dist`, evaluated
    /// on a test set of size `n` (binomial sampling).
    fn simulate(runs: usize, n: usize, p0: f64, sigma_dist: f64, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..runs)
            .map(|_| {
                let p = (p0 + sigma_dist * rng.normal() as f64).clamp(0.0, 1.0);
                let correct = (0..n).filter(|_| (rng.uniform() as f64) < p).count();
                correct as f64 / n as f64
            })
            .collect()
    }

    #[test]
    fn recovers_zero_dist_variance() {
        // Pure binomial noise: dist-wise estimate should be ~0, far below
        // the test-set stddev.
        let accs = simulate(600, 2000, 0.93, 0.0, 1);
        let d = decompose_variance(&accs, 2000);
        assert!(d.test_set_std > 0.003, "test std {}", d.test_set_std);
        assert!(
            d.dist_wise_std < d.test_set_std / 3.0,
            "dist {} vs test {}",
            d.dist_wise_std,
            d.test_set_std
        );
    }

    #[test]
    fn recovers_true_dist_variance() {
        let sigma = 0.01;
        let accs = simulate(800, 2000, 0.9, sigma, 2);
        let d = decompose_variance(&accs, 2000);
        assert!(
            (d.dist_wise_std - sigma).abs() < 0.003,
            "estimated {} true {sigma}",
            d.dist_wise_std
        );
    }

    #[test]
    fn never_negative() {
        // Tiny sample where sample variance may undershoot binomial.
        let accs = vec![0.9, 0.9, 0.9];
        let d = decompose_variance(&accs, 100);
        assert_eq!(d.dist_wise_std, 0.0);
    }

    #[test]
    fn paper_regime_ratio() {
        // Table 4 regime: test-set std ~0.13-0.16%, n_test = 10_000,
        // dist-wise std ~0.02-0.04% — at least 5x smaller. Our estimator
        // must reproduce the >=5x gap on simulated data in that regime.
        let accs = simulate(2000, 10_000, 0.94, 0.0003, 3);
        let d = decompose_variance(&accs, 10_000);
        assert!(
            d.dist_wise_std * 4.0 < d.test_set_std,
            "dist {} test {}",
            d.dist_wise_std,
            d.test_set_std
        );
    }
}
