//! Class-aggregated calibration error — CACE (Jiang et al. 2021), used by
//! paper §5.3 to show TTA trades calibration for lower test-set variance.
//!
//! Class-wise calibration demands `P(y = k | p_k(x) = q) = q` for every
//! class `k` and confidence `q`. CACE measures the aggregate deviation: bin
//! the predicted probability for each class, and average
//! `|mean confidence - empirical frequency|` across bins weighted by bin
//! mass, summed over classes.

use crate::tensor::Tensor;

/// Class-aggregated calibration error over `(N, K)` probabilities.
///
/// `CACE = sum_k sum_b (n_kb / N) * |conf_kb - freq_kb|`, with `bins`
/// equal-width probability bins per class (15 by default matches the
/// magnitude regime of the paper's reported values).
pub fn cace(probs: &Tensor, labels: &[u16], bins: usize) -> f64 {
    let k = probs.shape()[1];
    let n = probs.shape()[0];
    assert_eq!(labels.len(), n);
    let data = probs.data();
    let mut total = 0.0;
    for class in 0..k {
        let mut count = vec![0usize; bins];
        let mut conf = vec![0f64; bins];
        let mut hits = vec![0f64; bins];
        for i in 0..n {
            let p = data[i * k + class] as f64;
            let b = ((p * bins as f64) as usize).min(bins - 1);
            count[b] += 1;
            conf[b] += p;
            if labels[i] as usize == class {
                hits[b] += 1.0;
            }
        }
        for b in 0..bins {
            if count[b] == 0 {
                continue;
            }
            let m = count[b] as f64;
            total += (m / n as f64) * (conf[b] / m - hits[b] / m).abs();
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Build (probs, labels) where labels are drawn FROM the predicted
    /// distribution — perfectly class-wise calibrated by construction.
    fn calibrated_sample(n: usize, k: usize, seed: u64) -> (Tensor, Vec<u16>) {
        let mut rng = Rng::new(seed);
        let mut probs = Tensor::zeros(&[n, k]);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            // random distribution
            let mut row: Vec<f32> = (0..k).map(|_| rng.uniform() + 1e-3).collect();
            let s: f32 = row.iter().sum();
            for v in &mut row {
                *v /= s;
            }
            // sample label from it
            let u = rng.uniform();
            let mut acc = 0f32;
            let mut lab = k - 1;
            for (j, &p) in row.iter().enumerate() {
                acc += p;
                if u < acc {
                    lab = j;
                    break;
                }
            }
            labels.push(lab as u16);
            probs.data_mut()[i * k..(i + 1) * k].copy_from_slice(&row);
        }
        (probs, labels)
    }

    #[test]
    fn calibrated_predictions_have_low_cace() {
        let (probs, labels) = calibrated_sample(20_000, 10, 1);
        let c = cace(&probs, &labels, 15);
        assert!(c < 0.05, "calibrated CACE too high: {c}");
    }

    #[test]
    fn overconfident_predictions_have_high_cace() {
        // Predict 0.99 for a class that's right only half the time.
        let n = 2000;
        let k = 2;
        let mut probs = Tensor::zeros(&[n, k]);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            probs.data_mut()[i * k] = 0.99;
            probs.data_mut()[i * k + 1] = 0.01;
            labels.push((i % 2) as u16); // class 0 correct 50% of the time
        }
        let c = cace(&probs, &labels, 15);
        assert!(c > 0.5, "overconfident CACE too low: {c}");
    }

    #[test]
    fn cace_nonnegative_and_bounded() {
        let (probs, labels) = calibrated_sample(500, 10, 2);
        let c = cace(&probs, &labels, 15);
        assert!(c >= 0.0);
        assert!(c <= 2.0);
    }

    #[test]
    fn sharpening_increases_cace() {
        // Taking a calibrated predictor and sharpening its probabilities
        // (like TTA does to the ensemble) must increase CACE — the §5.3
        // hypothesis in miniature.
        let (probs, labels) = calibrated_sample(20_000, 10, 3);
        let mut sharp = probs.clone();
        let k = 10;
        for i in 0..20_000 {
            let row = &mut sharp.data_mut()[i * k..(i + 1) * k];
            let mut s = 0f32;
            for v in row.iter_mut() {
                *v = v.powf(2.0); // temperature < 1
                s += *v;
            }
            for v in row.iter_mut() {
                *v /= s;
            }
        }
        let c0 = cace(&probs, &labels, 15);
        let c1 = cace(&sharp, &labels, 15);
        assert!(c1 > c0, "sharpened {c1} <= calibrated {c0}");
    }
}
