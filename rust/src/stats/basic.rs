//! Basic sample statistics: mean, stddev, confidence intervals.
//!
//! Everything the paper's tables print next to an accuracy: `n`, mean,
//! between-run stddev, and the 95% CI half-widths shown in Figure 5.

/// Summary statistics of a sample.
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Sample mean.
    pub mean: f64,
    /// Sample (n-1) standard deviation.
    pub std: f64,
    /// Smallest value.
    pub min: f64,
    /// Largest value.
    pub max: f64,
}

/// Incremental (Welford) accumulator behind [`Summary`]: push values one at
/// a time, read the summary at any point. An n=10,000 fleet (Table 4 scale)
/// streams per-run accuracies through this — O(1) state, no need to hold
/// every per-run record in memory just to aggregate.
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: usize,
    mean: f64,
    /// Sum of squared deviations from the running mean.
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Welford {
        Welford::default()
    }

    /// Number of values pushed so far.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Add one value (Welford's update: numerically stable, single pass).
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    /// Merge another accumulator into this one (Chan et al.'s parallel
    /// variance combination). `a.merge(&b)` summarizes the concatenation of
    /// both streams: `n`/`min`/`max` combine exactly; `mean`/`m2` combine
    /// up to floating-point rounding (the merge is associative and
    /// commutative only to ~1e-12 — the property tests pin the tolerance).
    /// Distributed studies (ROADMAP) reduce per-worker accumulators
    /// through this.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Snapshot as a [`Summary`] (all-zeros when nothing was pushed, like
    /// `Summary::of(&[])`).
    pub fn summary(&self) -> Summary {
        if self.n == 0 {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
            };
        }
        let var = if self.n > 1 {
            self.m2 / (self.n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n: self.n,
            mean: self.mean,
            std: var.sqrt(),
            min: self.min,
            max: self.max,
        }
    }
}

impl Summary {
    /// Summarize a sample (all-zeros for an empty slice). Wrapper over the
    /// incremental [`Welford`] path, so batch and streaming aggregation can
    /// never disagree.
    pub fn of(xs: &[f64]) -> Summary {
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        w.summary()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std / (self.n as f64).sqrt()
        }
    }

    /// Half-width of the normal-approximation 95% CI (paper Fig 5's bars).
    pub fn ci95(&self) -> f64 {
        1.96 * self.sem()
    }
}

/// Welch's t-statistic for a difference in means (flip-option comparisons).
pub fn welch_t(a: &Summary, b: &Summary) -> f64 {
    let se = (a.sem().powi(2) + b.sem().powi(2)).sqrt();
    if se == 0.0 {
        0.0
    } else {
        (a.mean - b.mean) / se
    }
}

/// Histogram with fixed-width bins over `[lo, hi)` (Fig 6's accuracy
/// distributions).
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x >= lo && x < hi {
            h[((x - lo) / w) as usize] += 1;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean - 5.0).abs() < 1e-12);
        // sample std of that set is sqrt(32/7)
        assert!((s.std - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn empty_and_singleton() {
        let e = Summary::of(&[]);
        assert_eq!(e.n, 0);
        assert_eq!(e.ci95(), 0.0);
        let s = Summary::of(&[3.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a = Summary::of(&vec![1.0, 2.0, 3.0, 4.0]);
        let b = Summary::of(&[1.0, 2.0, 3.0, 4.0].repeat(25));
        assert!(b.ci95() < a.ci95() / 2.0);
    }

    #[test]
    fn welch_t_zero_for_identical() {
        let a = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(welch_t(&a, &a), 0.0);
        let b = Summary::of(&[11.0, 12.0, 13.0]);
        assert!(welch_t(&b, &a) > 5.0);
    }

    #[test]
    fn welford_streaming_matches_independent_two_pass() {
        // Reference computed INLINE with the classic two-pass formulas —
        // Summary::of now wraps Welford itself, so comparing against it
        // would be vacuous.
        fn two_pass(xs: &[f64]) -> (f64, f64, f64, f64) {
            let n = xs.len() as f64;
            let mean = xs.iter().sum::<f64>() / n;
            let var = if xs.len() > 1 {
                xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0)
            } else {
                0.0
            };
            let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
            let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            (mean, var.sqrt(), min, max)
        }
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0, -1.5, 0.25];
        let mut w = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            w.push(x);
            // At every prefix the stream agrees with the two-pass math.
            let s = w.summary();
            let (mean, std, min, max) = two_pass(&xs[..=i]);
            assert_eq!(s.n, i + 1);
            assert!((s.mean - mean).abs() < 1e-12);
            assert!((s.std - std).abs() < 1e-12);
            assert_eq!(s.min, min);
            assert_eq!(s.max, max);
        }
        assert_eq!(w.n(), xs.len());
        // Empty accumulator mirrors Summary::of(&[]).
        let e = Welford::new().summary();
        assert_eq!((e.n, e.mean, e.std, e.min, e.max), (0, 0.0, 0.0, 0.0, 0.0));
    }

    fn accumulate(xs: &[f64]) -> Welford {
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        w
    }

    fn summaries_close(a: &Summary, b: &Summary, tol: f64) -> bool {
        // n/min/max combine exactly under merge; mean/std only to FP
        // rounding.
        a.n == b.n
            && a.min == b.min
            && a.max == b.max
            && (a.mean - b.mean).abs() <= tol * (1.0 + a.mean.abs())
            && (a.std - b.std).abs() <= tol * (1.0 + a.std)
    }

    fn random_stream(r: &mut crate::rng::Rng, max_len: usize) -> Vec<f64> {
        let len = r.below(max_len + 1);
        (0..len)
            .map(|_| (r.uniform() as f64 - 0.5) * 200.0)
            .collect()
    }

    #[test]
    fn prop_welford_merge_matches_two_pass() {
        // merge(A, B) must agree with the naive two-pass mean/variance of
        // the concatenated stream.
        crate::util::proptest::check(
            "welford_merge_two_pass",
            crate::util::proptest::cases_from_env(100),
            |r| (random_stream(r, 40), random_stream(r, 40)),
            |(xs, ys)| {
                let mut merged = accumulate(xs);
                merged.merge(&accumulate(ys));
                let concat: Vec<f64> = xs.iter().chain(ys).copied().collect();
                summaries_close(&merged.summary(), &Summary::of(&concat), 1e-10)
            },
        );
    }

    #[test]
    fn prop_welford_merge_is_commutative() {
        crate::util::proptest::check(
            "welford_merge_commutative",
            crate::util::proptest::cases_from_env(100),
            |r| (random_stream(r, 40), random_stream(r, 40)),
            |(xs, ys)| {
                let mut ab = accumulate(xs);
                ab.merge(&accumulate(ys));
                let mut ba = accumulate(ys);
                ba.merge(&accumulate(xs));
                summaries_close(&ab.summary(), &ba.summary(), 1e-12)
            },
        );
    }

    #[test]
    fn prop_welford_merge_is_associative() {
        crate::util::proptest::check(
            "welford_merge_associative",
            crate::util::proptest::cases_from_env(100),
            |r| {
                (
                    random_stream(r, 30),
                    random_stream(r, 30),
                    random_stream(r, 30),
                )
            },
            |(xs, ys, zs)| {
                // (A + B) + C
                let mut left = accumulate(xs);
                left.merge(&accumulate(ys));
                left.merge(&accumulate(zs));
                // A + (B + C)
                let mut bc = accumulate(ys);
                bc.merge(&accumulate(zs));
                let mut right = accumulate(xs);
                right.merge(&bc);
                summaries_close(&left.summary(), &right.summary(), 1e-12)
            },
        );
    }

    #[test]
    fn welford_merge_edge_cases() {
        // empty + empty
        let mut w = Welford::new();
        w.merge(&Welford::new());
        assert_eq!(w.n(), 0);
        let s = w.summary();
        assert_eq!((s.mean, s.std, s.min, s.max), (0.0, 0.0, 0.0, 0.0));

        // empty + X and X + empty both equal X, bit-exactly.
        let x = accumulate(&[1.5, -2.0, 7.25]);
        let mut le = Welford::new();
        le.merge(&x);
        let mut re = x;
        re.merge(&Welford::new());
        for w in [&le, &re] {
            let s = w.summary();
            let want = Summary::of(&[1.5, -2.0, 7.25]);
            assert_eq!(s.n, want.n);
            assert_eq!(s.mean.to_bits(), want.mean.to_bits());
            assert_eq!(s.std.to_bits(), want.std.to_bits());
        }

        // singleton + singleton matches a two-element sample.
        let mut a = accumulate(&[3.0]);
        a.merge(&accumulate(&[5.0]));
        let s = a.summary();
        assert_eq!(s.n, 2);
        assert!((s.mean - 4.0).abs() < 1e-15);
        assert!((s.std - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!((s.min, s.max), (3.0, 5.0));
    }

    #[test]
    fn histogram_bins() {
        let h = histogram(&[0.05, 0.15, 0.15, 0.95], 0.0, 1.0, 10);
        assert_eq!(h[0], 1);
        assert_eq!(h[1], 2);
        assert_eq!(h[9], 1);
        assert_eq!(h.iter().sum::<usize>(), 4);
    }
}
