//! Basic sample statistics: mean, stddev, confidence intervals.
//!
//! Everything the paper's tables print next to an accuracy: `n`, mean,
//! between-run stddev, and the 95% CI half-widths shown in Figure 5.

/// Summary statistics of a sample.
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Sample mean.
    pub mean: f64,
    /// Sample (n-1) standard deviation.
    pub std: f64,
    /// Smallest value.
    pub min: f64,
    /// Largest value.
    pub max: f64,
}

/// Incremental (Welford) accumulator behind [`Summary`]: push values one at
/// a time, read the summary at any point. An n=10,000 fleet (Table 4 scale)
/// streams per-run accuracies through this — O(1) state, no need to hold
/// every per-run record in memory just to aggregate.
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: usize,
    mean: f64,
    /// Sum of squared deviations from the running mean.
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Welford {
        Welford::default()
    }

    /// Number of values pushed so far.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Add one value (Welford's update: numerically stable, single pass).
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    /// Merge another accumulator into this one (Chan et al.'s parallel
    /// variance combination). `a.merge(&b)` summarizes the concatenation of
    /// both streams: `n`/`min`/`max` combine exactly; `mean`/`m2` combine
    /// up to floating-point rounding (the merge is associative and
    /// commutative only to ~1e-12 — the property tests pin the tolerance).
    /// Distributed studies (ROADMAP) reduce per-worker accumulators
    /// through this.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Snapshot as a [`Summary`] (all-zeros when nothing was pushed, like
    /// `Summary::of(&[])`).
    pub fn summary(&self) -> Summary {
        if self.n == 0 {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
            };
        }
        let var = if self.n > 1 {
            self.m2 / (self.n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n: self.n,
            mean: self.mean,
            std: var.sqrt(),
            min: self.min,
            max: self.max,
        }
    }
}

impl Summary {
    /// Summarize a sample (all-zeros for an empty slice). Wrapper over the
    /// incremental [`Welford`] path, so batch and streaming aggregation can
    /// never disagree.
    pub fn of(xs: &[f64]) -> Summary {
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        w.summary()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std / (self.n as f64).sqrt()
        }
    }

    /// Half-width of the normal-approximation 95% CI (paper Fig 5's bars).
    pub fn ci95(&self) -> f64 {
        1.96 * self.sem()
    }
}

/// Welch's t-statistic for a difference in means (flip-option comparisons).
pub fn welch_t(a: &Summary, b: &Summary) -> f64 {
    let se = (a.sem().powi(2) + b.sem().powi(2)).sqrt();
    if se == 0.0 {
        0.0
    } else {
        (a.mean - b.mean) / se
    }
}

/// Streaming latency histogram with fixed log-spaced buckets.
///
/// Built for the serving tier (DESIGN.md §12): every request latency is
/// `record`ed in O(1) and p50/p90/p99 are read at any time without holding
/// the samples. Bucket `i` covers `(2^((i-1)/4), 2^(i/4)]` microseconds
/// (bucket 0 is everything at or below 1 µs, the last bucket is open-ended),
/// so [`Histogram::quantile`] returns the *upper edge* of the bucket holding
/// the exact order statistic: it never under-reports, and over-reports by at
/// most a factor of [`Histogram::RATIO`] (= 2^(1/4) ≈ 1.19) down to the 1 µs
/// resolution floor — the property tests pin both bounds against exact
/// sorted quantiles. Exact `n`/`mean`/`min`/`max` are tracked on the side,
/// and quantiles are clamped into `[min, max]`.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    /// Number of buckets: quarter-powers of two from 1 µs up to
    /// 2^(95/4) µs ≈ 14.1 s, plus the open-ended tail.
    pub const BUCKETS: usize = 96;

    /// Worst-case multiplicative over-report of a quantile (one bucket
    /// width): 2^(1/4).
    pub const RATIO: f64 = 1.189_207_115_002_721_1;

    /// Empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0; Histogram::BUCKETS],
            n: 0,
            sum: 0.0,
            min: 0.0,
            max: 0.0,
        }
    }

    /// Upper bucket edge in µs: `2^(i/4)` (the last bucket is open-ended;
    /// its nominal edge only matters as a quantile fallback before the
    /// min/max clamp).
    fn edge(i: usize) -> f64 {
        (2.0f64).powf(i as f64 / 4.0)
    }

    fn bucket(x: f64) -> usize {
        if x <= 1.0 {
            0
        } else {
            ((x.log2() * 4.0).ceil() as usize).min(Histogram::BUCKETS - 1)
        }
    }

    /// Record one latency in microseconds. Negative and NaN values are
    /// dropped (they can only come from clock bugs, and one bad sample
    /// must not poison `sum`/`min`).
    pub fn record(&mut self, x: f64) {
        if !x.is_finite() || x < 0.0 {
            return;
        }
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        self.sum += x;
        self.counts[Histogram::bucket(x)] += 1;
    }

    /// Number of recorded values.
    pub fn n(&self) -> u64 {
        self.n
    }

    /// Exact mean of the recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Exact smallest recorded value (0 when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Exact largest recorded value (0 when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// The q-quantile (q in `(0, 1]`): upper edge of the bucket holding the
    /// `ceil(q*n)`-th smallest value, clamped into `[min, max]`. Returns 0
    /// when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let rank = ((q * self.n as f64).ceil() as u64).clamp(1, self.n);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // The open-ended tail has no honest upper edge: report the
                // exact max rather than a nominal bound below it.
                if i + 1 == Histogram::BUCKETS {
                    return self.max;
                }
                return Histogram::edge(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Merge another histogram into this one (exact: bucket counts and the
    /// side statistics all combine losslessly). Per-client latency
    /// recorders in `bench --serve` reduce through this.
    pub fn merge(&mut self, other: &Histogram) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            self.min = other.min;
            self.max = other.max;
        } else {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
        self.n += other.n;
        self.sum += other.sum;
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }

    /// Snapshot as the metrics-wire latency block:
    /// `{"n", "mean_us", "min_us", "max_us", "p50_us", "p90_us", "p99_us"}`.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("n", Json::num(self.n as f64)),
            ("mean_us", Json::num(self.mean())),
            ("min_us", Json::num(self.min)),
            ("max_us", Json::num(self.max)),
            ("p50_us", Json::num(self.quantile(0.5))),
            ("p90_us", Json::num(self.quantile(0.9))),
            ("p99_us", Json::num(self.quantile(0.99))),
        ])
    }
}

/// Rolling-window wrapper over [`Histogram`]: a ring of per-second
/// histograms, so recent-latency quantiles (the serve `health` endpoint,
/// DESIGN.md §12) reflect only the last N seconds instead of being
/// diluted by cumulative history. The caller supplies time as whole
/// seconds from its own monotonic epoch (keeping the type clock-free and
/// testable); a slot is lazily reset when its second comes around again,
/// so idle periods cost nothing.
#[derive(Clone, Debug)]
pub struct RollingHistogram {
    /// `(second tag, that second's histogram)` per ring slot. The tag
    /// starts at `u64::MAX` ("never written"), which no window can match.
    slots: Vec<(u64, Histogram)>,
}

impl RollingHistogram {
    /// A ring covering the last `capacity_s` seconds (at least 1).
    pub fn new(capacity_s: usize) -> RollingHistogram {
        RollingHistogram {
            slots: vec![(u64::MAX, Histogram::new()); capacity_s.max(1)],
        }
    }

    /// The longest window this ring can answer, seconds.
    pub fn capacity_s(&self) -> usize {
        self.slots.len()
    }

    /// Record one latency at second `now_s`. Reuses (and resets) the ring
    /// slot whose second has lapped.
    pub fn record(&mut self, now_s: u64, x: f64) {
        let i = (now_s % self.slots.len() as u64) as usize;
        let (tag, h) = &mut self.slots[i];
        if *tag != now_s {
            *tag = now_s;
            *h = Histogram::new();
        }
        h.record(x);
    }

    /// Merge the slots covering `(now_s - window_s, now_s]` into one
    /// [`Histogram`] (the lossless bucket merge; `window_s` is clamped to
    /// the ring capacity).
    pub fn snapshot(&self, now_s: u64, window_s: u64) -> Histogram {
        let window = window_s.clamp(1, self.slots.len() as u64);
        let mut out = Histogram::new();
        for (tag, h) in &self.slots {
            if *tag <= now_s && now_s - *tag < window {
                out.merge(h);
            }
        }
        out
    }
}

/// Histogram with fixed-width bins over `[lo, hi)` (Fig 6's accuracy
/// distributions).
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x >= lo && x < hi {
            h[((x - lo) / w) as usize] += 1;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean - 5.0).abs() < 1e-12);
        // sample std of that set is sqrt(32/7)
        assert!((s.std - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn empty_and_singleton() {
        let e = Summary::of(&[]);
        assert_eq!(e.n, 0);
        assert_eq!(e.ci95(), 0.0);
        let s = Summary::of(&[3.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a = Summary::of(&vec![1.0, 2.0, 3.0, 4.0]);
        let b = Summary::of(&[1.0, 2.0, 3.0, 4.0].repeat(25));
        assert!(b.ci95() < a.ci95() / 2.0);
    }

    #[test]
    fn welch_t_zero_for_identical() {
        let a = Summary::of(&[1.0, 2.0, 3.0]);
        assert_eq!(welch_t(&a, &a), 0.0);
        let b = Summary::of(&[11.0, 12.0, 13.0]);
        assert!(welch_t(&b, &a) > 5.0);
    }

    #[test]
    fn welford_streaming_matches_independent_two_pass() {
        // Reference computed INLINE with the classic two-pass formulas —
        // Summary::of now wraps Welford itself, so comparing against it
        // would be vacuous.
        fn two_pass(xs: &[f64]) -> (f64, f64, f64, f64) {
            let n = xs.len() as f64;
            let mean = xs.iter().sum::<f64>() / n;
            let var = if xs.len() > 1 {
                xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0)
            } else {
                0.0
            };
            let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
            let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            (mean, var.sqrt(), min, max)
        }
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0, -1.5, 0.25];
        let mut w = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            w.push(x);
            // At every prefix the stream agrees with the two-pass math.
            let s = w.summary();
            let (mean, std, min, max) = two_pass(&xs[..=i]);
            assert_eq!(s.n, i + 1);
            assert!((s.mean - mean).abs() < 1e-12);
            assert!((s.std - std).abs() < 1e-12);
            assert_eq!(s.min, min);
            assert_eq!(s.max, max);
        }
        assert_eq!(w.n(), xs.len());
        // Empty accumulator mirrors Summary::of(&[]).
        let e = Welford::new().summary();
        assert_eq!((e.n, e.mean, e.std, e.min, e.max), (0, 0.0, 0.0, 0.0, 0.0));
    }

    fn accumulate(xs: &[f64]) -> Welford {
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        w
    }

    fn summaries_close(a: &Summary, b: &Summary, tol: f64) -> bool {
        // n/min/max combine exactly under merge; mean/std only to FP
        // rounding.
        a.n == b.n
            && a.min == b.min
            && a.max == b.max
            && (a.mean - b.mean).abs() <= tol * (1.0 + a.mean.abs())
            && (a.std - b.std).abs() <= tol * (1.0 + a.std)
    }

    fn random_stream(r: &mut crate::rng::Rng, max_len: usize) -> Vec<f64> {
        let len = r.below(max_len + 1);
        (0..len)
            .map(|_| (r.uniform() as f64 - 0.5) * 200.0)
            .collect()
    }

    #[test]
    fn prop_welford_merge_matches_two_pass() {
        // merge(A, B) must agree with the naive two-pass mean/variance of
        // the concatenated stream.
        crate::util::proptest::check(
            "welford_merge_two_pass",
            crate::util::proptest::cases_from_env(100),
            |r| (random_stream(r, 40), random_stream(r, 40)),
            |(xs, ys)| {
                let mut merged = accumulate(xs);
                merged.merge(&accumulate(ys));
                let concat: Vec<f64> = xs.iter().chain(ys).copied().collect();
                summaries_close(&merged.summary(), &Summary::of(&concat), 1e-10)
            },
        );
    }

    #[test]
    fn prop_welford_merge_is_commutative() {
        crate::util::proptest::check(
            "welford_merge_commutative",
            crate::util::proptest::cases_from_env(100),
            |r| (random_stream(r, 40), random_stream(r, 40)),
            |(xs, ys)| {
                let mut ab = accumulate(xs);
                ab.merge(&accumulate(ys));
                let mut ba = accumulate(ys);
                ba.merge(&accumulate(xs));
                summaries_close(&ab.summary(), &ba.summary(), 1e-12)
            },
        );
    }

    #[test]
    fn prop_welford_merge_is_associative() {
        crate::util::proptest::check(
            "welford_merge_associative",
            crate::util::proptest::cases_from_env(100),
            |r| {
                (
                    random_stream(r, 30),
                    random_stream(r, 30),
                    random_stream(r, 30),
                )
            },
            |(xs, ys, zs)| {
                // (A + B) + C
                let mut left = accumulate(xs);
                left.merge(&accumulate(ys));
                left.merge(&accumulate(zs));
                // A + (B + C)
                let mut bc = accumulate(ys);
                bc.merge(&accumulate(zs));
                let mut right = accumulate(xs);
                right.merge(&bc);
                summaries_close(&left.summary(), &right.summary(), 1e-12)
            },
        );
    }

    #[test]
    fn welford_merge_edge_cases() {
        // empty + empty
        let mut w = Welford::new();
        w.merge(&Welford::new());
        assert_eq!(w.n(), 0);
        let s = w.summary();
        assert_eq!((s.mean, s.std, s.min, s.max), (0.0, 0.0, 0.0, 0.0));

        // empty + X and X + empty both equal X, bit-exactly.
        let x = accumulate(&[1.5, -2.0, 7.25]);
        let mut le = Welford::new();
        le.merge(&x);
        let mut re = x;
        re.merge(&Welford::new());
        for w in [&le, &re] {
            let s = w.summary();
            let want = Summary::of(&[1.5, -2.0, 7.25]);
            assert_eq!(s.n, want.n);
            assert_eq!(s.mean.to_bits(), want.mean.to_bits());
            assert_eq!(s.std.to_bits(), want.std.to_bits());
        }

        // singleton + singleton matches a two-element sample.
        let mut a = accumulate(&[3.0]);
        a.merge(&accumulate(&[5.0]));
        let s = a.summary();
        assert_eq!(s.n, 2);
        assert!((s.mean - 4.0).abs() < 1e-15);
        assert!((s.std - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!((s.min, s.max), (3.0, 5.0));
    }

    /// Exact q-quantile of a sample: smallest value whose cumulative
    /// fraction reaches q (the definition `Histogram::quantile` bounds).
    fn exact_quantile(xs: &[f64], q: f64) -> f64 {
        let mut s = xs.to_vec();
        s.sort_by(f64::total_cmp);
        let rank = ((q * s.len() as f64).ceil() as usize).clamp(1, s.len());
        s[rank - 1]
    }

    #[test]
    fn latency_histogram_basics() {
        let mut h = Histogram::new();
        assert_eq!(h.n(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!((h.mean(), h.min(), h.max()), (0.0, 0.0, 0.0));

        for x in [100.0, 200.0, 400.0, 800.0] {
            h.record(x);
        }
        assert_eq!(h.n(), 4);
        assert_eq!(h.mean(), 375.0);
        assert_eq!((h.min(), h.max()), (100.0, 800.0));
        // p50 falls in the bucket holding 200.0; the upper edge can
        // over-report by at most one bucket ratio.
        let p50 = h.quantile(0.5);
        assert!((200.0..=200.0 * Histogram::RATIO).contains(&p50));
        // p100 is clamped to the exact max.
        assert_eq!(h.quantile(1.0), 800.0);

        // NaN / negative samples are dropped, not poisoning the stats.
        h.record(f64::NAN);
        h.record(-5.0);
        assert_eq!(h.n(), 4);

        // Sub-resolution values land in bucket 0 and clamp to exact max.
        let mut tiny = Histogram::new();
        tiny.record(0.25);
        tiny.record(0.5);
        assert_eq!(tiny.quantile(0.99), 0.5);

        // The open-ended tail bucket reports the exact max (no nominal
        // edge to bound it) — the RATIO bound only holds below ~8 s.
        let mut big = Histogram::new();
        big.record(1.0e9);
        big.record(2.0e9);
        assert_eq!(big.quantile(0.5), 2.0e9);
    }

    #[test]
    fn latency_histogram_merge_matches_single_stream() {
        let xs = [3.0, 17.0, 90_000.0, 1.0, 250.0, 0.75];
        let ys = [42.0, 42.0, 7.5e7, 600.0];
        let mut merged = Histogram::new();
        for &x in &xs {
            merged.record(x);
        }
        let mut other = Histogram::new();
        for &y in &ys {
            other.record(y);
        }
        merged.merge(&other);
        let mut single = Histogram::new();
        for &v in xs.iter().chain(&ys) {
            single.record(v);
        }
        assert_eq!(merged.n(), single.n());
        assert_eq!(merged.mean().to_bits(), single.mean().to_bits());
        for q in [0.5, 0.9, 0.99, 1.0] {
            assert_eq!(merged.quantile(q).to_bits(), single.quantile(q).to_bits());
        }
        // Merging an empty histogram is the identity.
        let before = merged.to_json().to_string();
        merged.merge(&Histogram::new());
        assert_eq!(merged.to_json().to_string(), before);
    }

    #[test]
    fn prop_histogram_quantile_bounds_vs_exact() {
        // For every q, the histogram quantile must sit within one bucket of
        // the exact sorted quantile: exact <= hist <= max(exact * RATIO, 1µs
        // resolution floor). This is the accuracy contract the serve
        // metrics p50/p90/p99 rely on.
        crate::util::proptest::check(
            "histogram_quantile_bounds",
            crate::util::proptest::cases_from_env(100),
            |r| {
                let len = r.below(60) + 1;
                // Latencies spanning sub-µs to ~8 s, log-uniform-ish —
                // below the open-ended tail, whose max-reporting behavior
                // is pinned separately in `latency_histogram_basics`.
                (0..len)
                    .map(|_| (2.0f64).powf((r.uniform() as f64) * 24.0 - 1.0))
                    .collect::<Vec<f64>>()
            },
            |xs| {
                let mut h = Histogram::new();
                for &x in xs {
                    h.record(x);
                }
                [0.5, 0.9, 0.99].iter().all(|&q| {
                    let exact = exact_quantile(xs, q);
                    let hist = h.quantile(q);
                    exact <= hist && hist <= (exact * Histogram::RATIO).max(1.0)
                })
            },
        );
    }

    #[test]
    fn prop_histogram_merge_is_commutative() {
        crate::util::proptest::check(
            "histogram_merge_commutative",
            crate::util::proptest::cases_from_env(100),
            |r| {
                let stream = |r: &mut crate::rng::Rng| {
                    let len = r.below(30);
                    (0..len)
                        .map(|_| (r.uniform() as f64) * 1e6)
                        .collect::<Vec<f64>>()
                };
                (stream(r), stream(r))
            },
            |(xs, ys)| {
                let acc = |vals: &[f64]| {
                    let mut h = Histogram::new();
                    for &v in vals {
                        h.record(v);
                    }
                    h
                };
                let mut ab = acc(xs);
                ab.merge(&acc(ys));
                let mut ba = acc(ys);
                ba.merge(&acc(xs));
                ab.n() == ba.n()
                    && ab.quantile(0.9).to_bits() == ba.quantile(0.9).to_bits()
                    && ab.min().to_bits() == ba.min().to_bits()
                    && ab.max().to_bits() == ba.max().to_bits()
            },
        );
    }

    #[test]
    fn rolling_histogram_windows_and_lapped_slots() {
        let mut r = RollingHistogram::new(5);
        assert_eq!(r.capacity_s(), 5);
        // Nothing recorded: every window is empty.
        assert_eq!(r.snapshot(100, 5).n(), 0);

        r.record(10, 100.0);
        r.record(11, 200.0);
        r.record(13, 400.0);
        // Window (8, 13]: all three. Window (12, 13]: just the last.
        assert_eq!(r.snapshot(13, 5).n(), 3);
        assert_eq!(r.snapshot(13, 1).n(), 1);
        assert_eq!(r.snapshot(13, 1).max(), 400.0);
        // Window math matches the lossless merge: mean over (11, 13].
        assert_eq!(r.snapshot(13, 2).mean(), 400.0);
        assert_eq!(r.snapshot(13, 3).mean(), 300.0);
        // Advancing time ages data out without any writes.
        assert_eq!(r.snapshot(17, 5).n(), 1);
        assert_eq!(r.snapshot(18, 5).n(), 0);
        // A lapped slot (13 and 18 share slot 3) resets on reuse.
        r.record(18, 800.0);
        let s = r.snapshot(18, 5);
        assert_eq!(s.n(), 1);
        assert_eq!(s.min(), 800.0);
        // Windows larger than the ring clamp to its capacity.
        assert_eq!(r.snapshot(18, 500).n(), 1);
        // A zero window still answers for the current second.
        assert_eq!(r.snapshot(18, 0).n(), 1);
    }

    #[test]
    fn histogram_bins() {
        let h = histogram(&[0.05, 0.15, 0.15, 0.95], 0.0, 1.0, 10);
        assert_eq!(h[0], 1);
        assert_eq!(h[1], 2);
        assert_eq!(h[9], 1);
        assert_eq!(h.iter().sum::<usize>(), 4);
    }
}
