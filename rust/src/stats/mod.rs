//! Statistics substrate for the paper's evaluation section.
//!
//! * [`basic`] — means, CIs, histograms (all tables; Fig 5/6);
//! * [`powerlaw`] — epochs-to-error fits + effective speedup (§5.2, Table 2);
//! * [`calibration`] — CACE (§5.3, Table 4);
//! * [`variance`] — distribution-wise variance decomposition (§5.3, Table 4);
//! * [`study`] — policy × seed grid summaries and seed-paired comparisons
//!   (`airbench.study/1`, DESIGN.md §11).

pub mod basic;
pub mod calibration;
pub mod powerlaw;
pub mod study;
pub mod variance;

pub use basic::{histogram, welch_t, Histogram, RollingHistogram, Summary};
pub use calibration::cace;
pub use powerlaw::{effective_speedup, fit_power_law, PowerLaw};
pub use study::{paired, PairedComparison, StudyCell, StudyResult};
pub use variance::{decompose_variance, VarianceDecomposition};
