//! Study statistics: per-cell summaries and seed-paired comparisons for
//! augmentation-policy × seed grids (DESIGN.md §11).
//!
//! A study runs the same per-run seed table (`fleet_seeds`) under every
//! policy cell, so run `k` of cell A and run `k` of cell B trained with the
//! *same* seed — cells are paired samples, and the right comparison is the
//! paired one: statistics of the per-seed differences `a_k - b_k`, not of
//! two independent means. That is how the paper can claim "alternating ≥
//! random in every case where flipping helps" (Table 2/6): under common
//! seeds the win fraction is a sharp, computable predicate instead of a
//! noisy two-sample test (Picard's *seed(3407)* regime, PAPERS.md).
//!
//! The wire form is the `airbench.study/1` document ([`SCHEMA`]); the
//! [`validate`] function is the strict schema check — exact key sets
//! (unknown keys rejected) and grid arity (`cells × runs` accuracies,
//! `C(P,2)` comparisons in canonical order) — run by the engine on every
//! study result and by `bench::validate_any` on committed report files.

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::fleet::FleetResult;
use crate::data::augment::Policy;
use crate::stats::basic::Summary;
use crate::util::json::Json;

/// Schema tag of the study report document.
pub const SCHEMA: &str = "airbench.study/1";

/// Seed-paired comparison of two study cells over a common seed table.
#[derive(Clone, Copy, Debug)]
pub struct PairedComparison {
    /// Number of seed pairs.
    pub n: usize,
    /// Mean of the per-seed differences `a_k - b_k`.
    pub mean_diff: f64,
    /// Sample (n-1) standard deviation of the differences.
    pub std_diff: f64,
    /// Half-width of the normal-approximation 95% CI on `mean_diff`.
    pub ci95_diff: f64,
    /// Fraction of seeds where `a_k >= b_k`.
    pub win_frac: f64,
}

impl PairedComparison {
    /// The paper's Table-style dominance predicate: A was at least as good
    /// as B under *every* common seed.
    pub fn a_never_loses(&self) -> bool {
        self.win_frac >= 1.0
    }
}

/// Compute the paired statistics of two equal-length, seed-aligned
/// accuracy vectors (`a[k]` and `b[k]` trained with the same seed).
pub fn paired(a: &[f64], b: &[f64]) -> Result<PairedComparison> {
    if a.is_empty() {
        bail!("paired comparison needs at least one seed pair");
    }
    if a.len() != b.len() {
        bail!(
            "paired comparison needs seed-aligned samples (got {} vs {})",
            a.len(),
            b.len()
        );
    }
    let diffs: Vec<f64> = a.iter().zip(b).map(|(x, y)| x - y).collect();
    let s = Summary::of(&diffs);
    let wins = a.iter().zip(b).filter(|(x, y)| x >= y).count();
    Ok(PairedComparison {
        n: a.len(),
        mean_diff: s.mean,
        std_diff: s.std,
        ci95_diff: s.ci95(),
        win_frac: wins as f64 / a.len() as f64,
    })
}

/// One grid cell: a policy and the fleet it ran.
#[derive(Clone, Debug)]
pub struct StudyCell {
    /// The augmentation policy of the cell.
    pub policy: Policy,
    /// The cell's fleet result (per-run accuracies in seed order,
    /// bit-identical to a standalone fleet of the same config).
    pub fleet: FleetResult,
}

/// The result of one study: every cell of the policy × seed grid.
#[derive(Clone, Debug)]
pub struct StudyResult {
    /// Runs per cell (the seed-table length).
    pub runs: usize,
    /// The common per-run seed table every cell trained under.
    pub seeds: Vec<u64>,
    /// One entry per policy, in grid order.
    pub cells: Vec<StudyCell>,
}

impl StudyResult {
    /// Seed-paired comparison of cell `a` against cell `b`.
    pub fn comparison(&self, a: usize, b: usize) -> Result<PairedComparison> {
        let get = |i: usize| -> Result<&StudyCell> {
            self.cells
                .get(i)
                .ok_or_else(|| anyhow::anyhow!("no study cell {i} (have {})", self.cells.len()))
        };
        paired(&get(a)?.fleet.accuracies, &get(b)?.fleet.accuracies)
    }

    /// The `airbench.study/1` report document: base config echo, the
    /// common seed table (seeds as strings — JSON numbers are f64 and
    /// would corrupt u64 seeds), per-cell Welford summaries, and all
    /// `C(P,2)` pairwise comparisons in canonical `(i, j), i < j` order.
    pub fn to_json(&self, cfg: &TrainConfig, backend: &str) -> Json {
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|cell| {
                let s = cell.fleet.summary();
                Json::obj(vec![
                    ("policy", cell.policy.to_json()),
                    ("name", Json::Str(cell.policy.name())),
                    ("n", Json::num(s.n as f64)),
                    ("mean", Json::num(s.mean)),
                    ("std", Json::num(s.std)),
                    ("ci95", Json::num(s.ci95())),
                    ("min", Json::num(s.min)),
                    ("max", Json::num(s.max)),
                    (
                        "accs",
                        Json::Arr(cell.fleet.accuracies.iter().map(|&a| Json::num(a)).collect()),
                    ),
                ])
            })
            .collect();
        let mut comparisons = Vec::new();
        for i in 0..self.cells.len() {
            for j in i + 1..self.cells.len() {
                // Both cells completed, so the pairing cannot fail.
                let c = self
                    .comparison(i, j)
                    .expect("completed cells have aligned accuracy vectors");
                comparisons.push(Json::obj(vec![
                    ("a", Json::num(i as f64)),
                    ("b", Json::num(j as f64)),
                    ("a_name", Json::Str(self.cells[i].policy.name())),
                    ("b_name", Json::Str(self.cells[j].policy.name())),
                    ("n", Json::num(c.n as f64)),
                    ("mean_diff", Json::num(c.mean_diff)),
                    ("std_diff", Json::num(c.std_diff)),
                    ("ci95_diff", Json::num(c.ci95_diff)),
                    ("win_frac", Json::num(c.win_frac)),
                ]));
            }
        }
        Json::obj(vec![
            ("schema", Json::str(SCHEMA)),
            ("config", cfg.to_json()),
            ("backend", Json::str(backend)),
            ("runs", Json::num(self.runs as f64)),
            (
                "seeds",
                Json::Arr(self.seeds.iter().map(|s| Json::str(&s.to_string())).collect()),
            ),
            ("cells", Json::Arr(cells)),
            ("comparisons", Json::Arr(comparisons)),
        ])
    }
}

/// Exact-key-set check: every present key must be declared, every required
/// key must be present.
fn exact_keys(j: &Json, what: &str, required: &[&str], optional: &[&str]) -> Result<()> {
    let obj = j.as_obj().with_context(|| format!("{what} must be an object"))?;
    for k in obj.keys() {
        if !required.contains(&k.as_str()) && !optional.contains(&k.as_str()) {
            bail!("{what}: unknown key '{k}'");
        }
    }
    for r in required {
        if !obj.contains_key(*r) {
            bail!("{what}: missing key '{r}'");
        }
    }
    Ok(())
}

fn finite(j: &Json, what: &str, key: &str) -> Result<f64> {
    let x = j.get(key)?.as_f64()?;
    if !x.is_finite() {
        bail!("{what}: '{key}' = {x} is not finite");
    }
    Ok(x)
}

fn finite_unit(j: &Json, what: &str, key: &str) -> Result<f64> {
    let x = finite(j, what, key)?;
    if !(0.0..=1.0).contains(&x) {
        bail!("{what}: '{key}' = {x} is outside [0, 1]");
    }
    Ok(x)
}

/// Strict `airbench.study/1` validator: schema tag, exact key sets at
/// every level (unknown keys rejected), and grid arity — `seeds` and every
/// cell's `accs` are `runs` long, and `comparisons` is exactly the
/// `C(cells, 2)` enumeration in `(i, j), i < j` order with names matching
/// the cells they index.
pub fn validate(j: &Json) -> Result<()> {
    exact_keys(
        j,
        "study report",
        &["schema", "config", "backend", "runs", "seeds", "cells", "comparisons"],
        &["log"],
    )?;
    let schema = j.get("schema")?.as_str()?;
    if schema != SCHEMA {
        bail!("study report: schema '{schema}' != '{SCHEMA}'");
    }
    j.get("config")?.get("variant")?.as_str()?;
    j.get("backend")?.as_str()?;
    let runs = j.get("runs")?.as_usize()?;
    if runs == 0 {
        bail!("study report: 'runs' must be >= 1");
    }
    let seeds = j.get("seeds")?.as_arr()?;
    if seeds.len() != runs {
        bail!("study report: {} seeds for runs={runs}", seeds.len());
    }
    for s in seeds {
        let s = s.as_str()?;
        if s.parse::<u64>().is_err() {
            bail!("study report: seed '{s}' is not a u64 string");
        }
    }
    let cells = j.get("cells")?.as_arr()?;
    if cells.is_empty() {
        bail!("study report: 'cells' must be non-empty");
    }
    let mut names = Vec::with_capacity(cells.len());
    for (i, cell) in cells.iter().enumerate() {
        let what = format!("study cell {i}");
        exact_keys(
            cell,
            &what,
            &["policy", "name", "n", "mean", "std", "ci95", "min", "max", "accs"],
            &[],
        )?;
        let policy = Policy::from_json(cell.get("policy")?)
            .with_context(|| format!("{what}: bad policy"))?;
        let name = cell.get("name")?.as_str()?;
        if name != policy.name() {
            bail!("{what}: name '{name}' != policy spelling '{}'", policy.name());
        }
        if cell.get("n")?.as_usize()? != runs {
            bail!("{what}: 'n' != runs={runs}");
        }
        for key in ["mean", "std", "ci95", "min", "max"] {
            finite(cell, &what, key)?;
        }
        let accs = cell.get("accs")?.as_arr()?;
        if accs.len() != runs {
            bail!("{what}: {} accs for runs={runs}", accs.len());
        }
        for (k, a) in accs.iter().enumerate() {
            let a = a.as_f64()?;
            if !a.is_finite() || !(0.0..=1.0).contains(&a) {
                bail!("{what}: accs[{k}] = {a} is not an accuracy in [0, 1]");
            }
        }
        names.push(name.to_string());
    }
    let comparisons = j.get("comparisons")?.as_arr()?;
    let expected = cells.len() * (cells.len() - 1) / 2;
    if comparisons.len() != expected {
        bail!(
            "study report: {} comparisons for {} cells (want C({}, 2) = {expected})",
            comparisons.len(),
            cells.len(),
            cells.len()
        );
    }
    let mut it = comparisons.iter();
    for i in 0..cells.len() {
        for jx in i + 1..cells.len() {
            let c = it.next().expect("length checked above");
            let what = format!("study comparison ({i}, {jx})");
            exact_keys(
                c,
                &what,
                &["a", "b", "a_name", "b_name", "n", "mean_diff", "std_diff", "ci95_diff", "win_frac"],
                &[],
            )?;
            if c.get("a")?.as_usize()? != i || c.get("b")?.as_usize()? != jx {
                bail!("{what}: out of canonical (i, j) i<j order");
            }
            if c.get("a_name")?.as_str()? != names[i] || c.get("b_name")?.as_str()? != names[jx] {
                bail!("{what}: names do not match the cells they index");
            }
            if c.get("n")?.as_usize()? != runs {
                bail!("{what}: 'n' != runs={runs}");
            }
            for key in ["mean_diff", "std_diff", "ci95_diff"] {
                finite(c, &what, key)?;
            }
            finite_unit(c, &what, "win_frac")?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::augment::FlipMode;

    fn fake_cell(flip: FlipMode, accs: &[f64]) -> StudyCell {
        // The report reads only the accuracy vectors; per-run records can
        // stay empty in a synthetic cell.
        StudyCell {
            policy: Policy::flip_only(flip),
            fleet: FleetResult {
                runs: Vec::new(),
                accuracies: accs.to_vec(),
                accuracies_no_tta: accs.to_vec(),
                times: vec![0.0; accs.len()],
                epochs_to_target: vec![None; accs.len()],
            },
        }
    }

    fn fake_study() -> StudyResult {
        StudyResult {
            runs: 4,
            seeds: vec![11, 22, 33, 44],
            cells: vec![
                fake_cell(FlipMode::Alternating, &[0.75, 0.5, 0.875, 0.625]),
                fake_cell(FlipMode::Random, &[0.5, 0.5, 0.75, 0.75]),
            ],
        }
    }

    #[test]
    fn paired_known_values() {
        let c = paired(&[0.75, 0.5, 0.875, 0.625], &[0.5, 0.5, 0.75, 0.75]).unwrap();
        assert_eq!(c.n, 4);
        // diffs: [0.25, 0, 0.125, -0.125] — dyadic, so mean is exact.
        assert_eq!(c.mean_diff, 0.0625);
        assert_eq!(c.win_frac, 0.75);
        assert!((c.std_diff - (0.078125f64 / 3.0).sqrt()).abs() < 1e-15);
        assert!((c.ci95_diff - 1.96 * c.std_diff / 2.0).abs() < 1e-15);
        assert!(!c.a_never_loses());
        assert!(paired(&[0.5, 0.5], &[0.25, 0.5]).unwrap().a_never_loses());
    }

    #[test]
    fn paired_rejects_misaligned_or_empty() {
        assert!(paired(&[], &[]).is_err());
        assert!(paired(&[0.5], &[0.5, 0.6]).is_err());
    }

    #[test]
    fn report_round_trips_through_its_own_validator() {
        let study = fake_study();
        let cfg = TrainConfig::default();
        let j = study.to_json(&cfg, "native");
        validate(&j).unwrap();
        // With the optional 'log' key (as the engine envelope adds it).
        let mut with_log = j.clone();
        if let Json::Obj(m) = &mut with_log {
            m.insert("log".to_string(), Json::Null);
        }
        validate(&with_log).unwrap();
    }

    #[test]
    fn validator_rejects_unknown_keys_and_wrong_arity() {
        let study = fake_study();
        let cfg = TrainConfig::default();
        let good = study.to_json(&cfg, "native");

        // Unknown top-level key.
        let mut j = good.clone();
        if let Json::Obj(m) = &mut j {
            m.insert("extra".to_string(), Json::num(1.0));
        }
        assert!(validate(&j).is_err());

        // Wrong-arity grid: a cell with a truncated accuracy vector.
        let mut j = good.clone();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Arr(cells)) = m.get_mut("cells") {
                if let Json::Obj(cell) = &mut cells[0] {
                    cell.insert("accs".to_string(), Json::Arr(vec![Json::num(0.5)]));
                }
            }
        }
        assert!(validate(&j).is_err());

        // Missing comparisons for the number of cells.
        let mut j = good.clone();
        if let Json::Obj(m) = &mut j {
            m.insert("comparisons".to_string(), Json::Arr(vec![]));
        }
        assert!(validate(&j).is_err());

        // Wrong schema tag.
        let mut j = good;
        if let Json::Obj(m) = &mut j {
            m.insert("schema".to_string(), Json::str("airbench.study/9"));
        }
        assert!(validate(&j).is_err());
    }

    #[test]
    fn comparison_indexes_cells() {
        let study = fake_study();
        let c = study.comparison(0, 1).unwrap();
        assert_eq!(c.mean_diff, 0.0625);
        let r = study.comparison(1, 0).unwrap();
        assert_eq!(r.mean_diff, -0.0625);
        assert!(study.comparison(0, 2).is_err());
    }
}
