//! Power-law epochs-to-error fits and effective-speedup estimation
//! (paper §5.2, Table 2).
//!
//! The paper fits `error = c + b * epochs^a` to each random-flip
//! configuration's (epochs, error) points, then reports the *effective
//! speedup* of alternating flip: if altflip at E epochs reaches an error
//! the fitted random-flip curve predicts at E' epochs, the speedup is
//! `E'/E - 1` (e.g. 20 -> 25.3 epochs = 27%).

/// Fitted `error = c + b * epochs^a` curve.
#[derive(Clone, Copy, Debug)]
pub struct PowerLaw {
    /// Exponent (negative for decreasing error curves).
    pub a: f64,
    /// Scale coefficient.
    pub b: f64,
    /// Asymptotic error floor.
    pub c: f64,
    /// Sum of squared residuals at the fit.
    pub sse: f64,
}

impl PowerLaw {
    /// Predicted error at `epochs`.
    pub fn predict(&self, epochs: f64) -> f64 {
        self.c + self.b * epochs.powf(self.a)
    }

    /// Invert: epochs at which the curve reaches `error`. `None` when the
    /// error is at/below the asymptote `c` (unreachable by this curve) or
    /// the fit is degenerate.
    pub fn epochs_for_error(&self, error: f64) -> Option<f64> {
        if self.b <= 0.0 || self.a >= 0.0 {
            return None;
        }
        let t = (error - self.c) / self.b;
        if t <= 0.0 {
            return None;
        }
        Some(t.powf(1.0 / self.a))
    }
}

/// Fit `error = c + b * epochs^a` by grid search over the exponent `a`
/// (log-spaced), solving the conditional linear least squares for (b, c)
/// in closed form at each candidate.
pub fn fit_power_law(epochs: &[f64], errors: &[f64]) -> Option<PowerLaw> {
    assert_eq!(epochs.len(), errors.len());
    let n = epochs.len();
    if n < 3 {
        return None;
    }
    let mut best: Option<PowerLaw> = None;
    // a in [-4, -0.05], dense log grid.
    for i in 0..400 {
        let a = -(0.05f64 * (4.0f64 / 0.05).powf(i as f64 / 399.0));
        // Linear LS on z = epochs^a: error ~ c + b z.
        let zs: Vec<f64> = epochs.iter().map(|e| e.powf(a)).collect();
        let zm = zs.iter().sum::<f64>() / n as f64;
        let ym = errors.iter().sum::<f64>() / n as f64;
        let mut num = 0.0;
        let mut den = 0.0;
        for (z, y) in zs.iter().zip(errors) {
            num += (z - zm) * (y - ym);
            den += (z - zm) * (z - zm);
        }
        if den < 1e-18 {
            continue;
        }
        let b = num / den;
        let c = ym - b * zm;
        let sse: f64 = zs
            .iter()
            .zip(errors)
            .map(|(z, y)| {
                let r = y - (c + b * z);
                r * r
            })
            .sum();
        if best.map_or(true, |p| sse < p.sse) {
            best = Some(PowerLaw { a, b, c, sse });
        }
    }
    best
}

/// The paper's effective-speedup estimator (§5.2): fit the power law to the
/// *baseline* (random flip) epochs-vs-error points, then ask how many
/// baseline epochs would be needed to reach the *treatment* (altflip)
/// error observed at `epochs`.
///
/// Returns the fractional speedup (0.27 = "27%"), or `None` if the
/// treatment error is below the fitted asymptote (infinite speedup regime —
/// the paper's 102% row is near this edge) or the fit fails.
pub fn effective_speedup(
    baseline_epochs: &[f64],
    baseline_errors: &[f64],
    epochs: f64,
    treatment_error: f64,
) -> Option<f64> {
    let fit = fit_power_law(baseline_epochs, baseline_errors)?;
    let equivalent = fit.epochs_for_error(treatment_error)?;
    Some(equivalent / epochs - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn recovers_exact_power_law() {
        // error = 0.05 + 0.5 * e^-0.7
        let epochs: Vec<f64> = vec![5.0, 10.0, 20.0, 40.0, 80.0];
        let errors: Vec<f64> = epochs.iter().map(|e| 0.05 + 0.5 * e.powf(-0.7)).collect();
        let fit = fit_power_law(&epochs, &errors).unwrap();
        assert!((fit.a - -0.7).abs() < 0.02, "a = {}", fit.a);
        assert!((fit.c - 0.05).abs() < 0.005, "c = {}", fit.c);
        assert!(fit.sse < 1e-6);
    }

    #[test]
    fn predict_invert_round_trip() {
        let fit = PowerLaw {
            a: -0.8,
            b: 0.4,
            c: 0.06,
            sse: 0.0,
        };
        for e in [4.0, 16.0, 64.0] {
            let err = fit.predict(e);
            let back = fit.epochs_for_error(err).unwrap();
            assert!((back - e).abs() / e < 1e-9);
        }
    }

    #[test]
    fn unreachable_error_returns_none() {
        let fit = PowerLaw {
            a: -0.8,
            b: 0.4,
            c: 0.06,
            sse: 0.0,
        };
        assert!(fit.epochs_for_error(0.05).is_none());
        assert!(fit.epochs_for_error(0.06).is_none());
    }

    #[test]
    fn speedup_matches_paper_example_shape() {
        // Paper example: random flip 6.26% @ 20ep, 5.99% @ 40ep; altflip
        // 6.13% @ 20ep -> power-law says 25.3 epochs -> 27% speedup.
        // We reproduce the *procedure* on an exact curve: baseline error
        // curve e(E) = 0.05 + 0.3 E^-1; treatment at 20 epochs achieves the
        // error of the 25-epoch baseline; expected speedup = 0.25.
        let epochs: Vec<f64> = vec![10.0, 20.0, 40.0, 80.0];
        let errors: Vec<f64> = epochs.iter().map(|e| 0.05 + 0.3 / e).collect();
        let treatment = 0.05 + 0.3 / 25.0;
        let s = effective_speedup(&epochs, &errors, 20.0, treatment).unwrap();
        assert!((s - 0.25).abs() < 0.01, "{s}");
    }

    #[test]
    fn robust_to_noise() {
        let mut rng = Rng::new(1);
        let epochs: Vec<f64> = vec![5.0, 10.0, 20.0, 40.0, 80.0, 160.0];
        let errors: Vec<f64> = epochs
            .iter()
            .map(|e| 0.07 + 0.6 * e.powf(-0.9) + 0.001 * rng.normal() as f64)
            .collect();
        let fit = fit_power_law(&epochs, &errors).unwrap();
        // prediction at an interior point is close to the true curve
        let truth = 0.07 + 0.6 * 30f64.powf(-0.9);
        assert!((fit.predict(30.0) - truth).abs() < 0.01);
    }

    #[test]
    fn too_few_points() {
        assert!(fit_power_law(&[1.0, 2.0], &[0.5, 0.4]).is_none());
    }
}
