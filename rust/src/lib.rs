//! # airbench-rs
//!
//! A Rust + JAX + Pallas reproduction of *"94% on CIFAR-10 in 3.29 Seconds
//! on a Single GPU"* (Keller Jordan, 2024).
//!
//! Three layers (see `DESIGN.md`):
//! - **L3 (this crate)** — the training coordinator: data pipeline and
//!   augmentation policies (including the paper's *alternating flip*),
//!   whitening/dirac initialization, LR + Lookahead schedules, the paper's
//!   timing protocol, multi-crop TTA evaluation, and fleet runners for the
//!   paper's statistical experiments.
//! - **L2** — the airbench CNN + Nesterov-SGD train step, written in JAX
//!   (`python/compile/model.py`), AOT-lowered to HLO text.
//! - **L1** — a tiled Pallas MXU matmul kernel that every convolution's
//!   forward *and* backward pass runs through
//!   (`python/compile/kernels/matmul.py`).
//!
//! At runtime only this crate runs: [`coordinator`] drives the
//! [`runtime::Backend`] seam — either the compiled PJRT path
//! (`artifacts/*.hlo.txt` via the `xla` crate) or the pure-Rust
//! multi-threaded [`runtime::native`] backend (its hot path is the blocked
//! GEMM microkernel in `runtime::native::gemm` — DESIGN.md §2.1), selected
//! by `--backend auto|pjrt|native` (DESIGN.md §2). The [`bench`] module is
//! the §3.7 measurement harness behind `airbench bench` (BENCHMARKS.md).
//!
//! The public programmatic surface is the [`api`] job layer (DESIGN.md
//! §9): typed [`api::JobSpec`]s executed by an [`api::Engine`] that
//! streams typed [`api::Event`]s with cancellation — the CLI is a thin
//! client of it, and [`serve`] exposes the same surface as a
//! newline-delimited-JSON daemon (`airbench serve`).
//!
//! # Quickstart
//!
//! Train the CPU-scale `bench` variant on the native backend (no
//! artifacts, no downloads — synthetic data is generated on the fly):
//!
//! ```bash
//! cargo run --release -- train --backend native epochs=2
//! ```
//!
//! Or drive a backend directly:
//!
//! ```
//! use airbench::runtime::{create_default_backend, Backend, BackendKind, InitConfig};
//!
//! let engine = create_default_backend(BackendKind::Native, "nano").unwrap();
//! let state = engine.init_state(&InitConfig::default());
//! assert_eq!(engine.name(), "native");
//! assert!(state.tensors.contains_key("head_w"));
//! ```

#![warn(missing_docs)]

pub mod api;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod tensor;
pub mod util;
pub mod whitening;

/// Crate version (for `airbench --version`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
