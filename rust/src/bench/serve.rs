//! The serve load phase (`airbench bench --serve`): closed-loop synthetic
//! clients driving single-image `predict_one` jobs through an in-process
//! [`Engine`](crate::api::Engine), timed once per requested `--max-batch`
//! level, so the committed `BENCH_*.json` trajectory records what request
//! coalescing (DESIGN.md §12) actually buys on this machine.
//!
//! Protocol per level: a fresh engine with the level's
//! [`BatcherConfig`](crate::serve::batcher::BatcherConfig), a synthetic
//! warm model inserted into its registry (no checkpoint IO — the phase
//! measures serving, not loading), one untimed warmup request, then
//! `clients` threads each issuing `requests` sequential predicts (closed
//! loop: a client's next request waits for its previous reply). Latencies
//! stream into per-client [`Histogram`]s merged per level; batch counters
//! come from a `metrics` job diffed around the timed window.
//!
//! Determinism is measured, not assumed: every request's `probs_md5` is
//! collected in (client, request) order and compared bitwise against the
//! first level's — `bit_identical_to_b1` next to `speedup_vs_b1`, exactly
//! like the fleet phase's determinism verdict.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::api::{Engine, EngineConfig, JobResult, JobSpec, MetricsJob, PredictOneJob, WarmModel};
use crate::coordinator::observer::{Cancelled, NullObserver, Observer};
use crate::experiments::DataKind;
use crate::runtime::checkpoint::state_md5;
use crate::runtime::native::{available_cores, builtin_variant};
use crate::runtime::{InitConfig, ModelState, NativeShared};
use crate::serve::batcher::BatcherConfig;
use crate::stats::basic::Histogram;
use crate::util::json::Json;

/// Schema identifier of serve load reports (`airbench bench --serve`).
pub const SERVE_SCHEMA: &str = "airbench.serve-bench/1";

/// Configuration of the serve load phase.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeBenchConfig {
    /// Variant to serve (native built-ins only — the batcher is a native
    /// worker).
    pub variant: String,
    /// Tag for `BENCH_<tag>.json`; defaults to `native_serve`.
    pub tag: Option<String>,
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Requests per client (total per level = `clients x requests`).
    pub requests: usize,
    /// `max_batch` levels to time, in order; `max_batch_levels[0]` is the
    /// speedup baseline (conventionally 1 = unbatched).
    pub max_batch_levels: Vec<usize>,
    /// Batcher flush deadline (µs a queued request may wait for company).
    pub max_wait_us: u64,
    /// Admission-queue bound (requests beyond it are rejected
    /// `overloaded`).
    pub queue_cap: usize,
    /// Test-split size requests index into.
    pub test_n: usize,
    /// Directory the JSON report is written to (repo root by convention).
    pub out_dir: PathBuf,
}

impl Default for ServeBenchConfig {
    fn default() -> Self {
        ServeBenchConfig {
            variant: "nano".into(),
            tag: None,
            clients: 8,
            requests: 32,
            max_batch_levels: vec![1, 8, 32],
            max_wait_us: 2_000,
            queue_cap: 256,
            test_n: 256,
            out_dir: PathBuf::from("."),
        }
    }
}

/// One timed `max_batch` level of the serve phase.
#[derive(Clone, Debug)]
pub struct ServeLevel {
    /// Batcher flush size this level ran with.
    pub max_batch: usize,
    /// Wall-clock seconds for all `clients x requests` predicts.
    pub wall_s: f64,
    /// Throughput: total requests / `wall_s`.
    pub req_per_s: f64,
    /// `eval_logits` calls the batcher issued inside the timed window.
    pub batches: usize,
    /// Mean coalesced requests per batch inside the timed window.
    pub mean_batch: f64,
    /// Requests rejected `overloaded` inside the timed window.
    pub rejected: usize,
    /// End-to-end request latencies (merged across clients).
    pub latency: Histogram,
    /// `wall_s(levels[0]) / wall_s(this)`.
    pub speedup_vs_b1: f64,
    /// Whether every request's `probs_md5` matched the first level's, in
    /// (client, request) order — the batcher's bit-identity contract,
    /// measured.
    pub bit_identical_to_b1: bool,
}

/// Everything one serve-phase invocation measured.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// File tag (`BENCH_<tag>.json`).
    pub tag: String,
    /// Backend the batcher worker ran (always `"native"`).
    pub backend_name: String,
    /// Variant served.
    pub variant: String,
    /// Cores of the measuring machine.
    pub cores: usize,
    /// Protocol knobs, echoed for reproducibility.
    pub config: ServeBenchConfig,
    /// One entry per `max_batch_levels` element, in order.
    pub levels: Vec<ServeLevel>,
}

impl ServeReport {
    /// The machine-readable report (schema documented in BENCHMARKS.md).
    pub fn to_json(&self) -> Json {
        let c = &self.config;
        Json::obj(vec![
            ("schema", Json::str(SERVE_SCHEMA)),
            ("tag", Json::str(&self.tag)),
            ("backend", Json::str(&self.backend_name)),
            ("variant", Json::str(&self.variant)),
            (
                "created_unix",
                Json::num(
                    std::time::SystemTime::now()
                        .duration_since(std::time::UNIX_EPOCH)
                        .map(|d| d.as_secs() as f64)
                        .unwrap_or(0.0),
                ),
            ),
            (
                "protocol",
                Json::obj(vec![
                    ("clients", Json::num(c.clients as f64)),
                    ("requests_per_client", Json::num(c.requests as f64)),
                    (
                        "max_batch_levels",
                        Json::Arr(
                            c.max_batch_levels.iter().map(|&x| Json::num(x as f64)).collect(),
                        ),
                    ),
                    ("max_wait_us", Json::num(c.max_wait_us as f64)),
                    ("queue_cap", Json::num(c.queue_cap as f64)),
                    ("test_n", Json::num(c.test_n as f64)),
                    ("data", Json::str("synthetic-cifar")),
                ]),
            ),
            (
                "env",
                Json::obj(vec![
                    ("cores", Json::num(self.cores as f64)),
                    ("os", Json::str(std::env::consts::OS)),
                    ("arch", Json::str(std::env::consts::ARCH)),
                ]),
            ),
            (
                "levels",
                Json::Arr(
                    self.levels
                        .iter()
                        .map(|l| {
                            Json::obj(vec![
                                ("max_batch", Json::num(l.max_batch as f64)),
                                ("wall_s", Json::num(l.wall_s)),
                                ("req_per_s", Json::num(l.req_per_s)),
                                ("batches", Json::num(l.batches as f64)),
                                ("mean_batch", Json::num(l.mean_batch)),
                                ("rejected", Json::num(l.rejected as f64)),
                                ("latency", l.latency.to_json()),
                                ("speedup_vs_b1", Json::num(l.speedup_vs_b1)),
                                ("bit_identical_to_b1", Json::Bool(l.bit_identical_to_b1)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write `BENCH_<tag>.json` into `dir` (schema-validated first).
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        let j = self.to_json();
        validate_serve(&j).context("serve phase produced a schema-invalid report")?;
        let path = dir.join(format!("BENCH_{}.json", self.tag));
        std::fs::write(&path, j.to_pretty_string())
            .with_context(|| format!("writing {path:?}"))?;
        Ok(path)
    }
}

/// Validate a serve load `BENCH_*.json` against [`SERVE_SCHEMA`].
pub fn validate_serve(j: &Json) -> Result<()> {
    let schema = j.get("schema")?.as_str()?;
    if schema != SERVE_SCHEMA {
        bail!("unknown serve-bench schema '{schema}' (want '{SERVE_SCHEMA}')");
    }
    for key in ["tag", "backend", "variant"] {
        if j.get(key)?.as_str()?.is_empty() {
            bail!("'{key}' must be a non-empty string");
        }
    }
    j.get("created_unix")?.as_f64()?;
    let proto = j.get("protocol")?;
    if proto.get("clients")?.as_usize()? == 0 {
        bail!("protocol.clients must be >= 1");
    }
    if proto.get("requests_per_client")?.as_usize()? == 0 {
        bail!("protocol.requests_per_client must be >= 1");
    }
    let levels_decl = proto.get("max_batch_levels")?.as_arr()?.len();
    for key in ["max_wait_us", "queue_cap", "test_n"] {
        proto.get(key)?.as_f64()?;
    }
    let env = j.get("env")?;
    if env.get("cores")?.as_usize()? == 0 {
        bail!("env.cores must be >= 1");
    }
    env.get("os")?.as_str()?;
    env.get("arch")?.as_str()?;
    let levels = j.get("levels")?.as_arr()?;
    if levels.is_empty() || levels.len() != levels_decl {
        bail!(
            "levels length {} must match protocol.max_batch_levels length {levels_decl} (and be >= 1)",
            levels.len()
        );
    }
    for (i, l) in levels.iter().enumerate() {
        if l.get("max_batch")?.as_usize()? == 0 {
            bail!("levels[{i}].max_batch must be >= 1");
        }
        for key in ["wall_s", "req_per_s", "mean_batch", "speedup_vs_b1"] {
            let x = l.get(key)?.as_f64()?;
            if !x.is_finite() {
                bail!("levels[{i}].{key} is not finite");
            }
        }
        if l.get("wall_s")?.as_f64()? <= 0.0 {
            bail!("levels[{i}].wall_s must be positive");
        }
        if l.get("mean_batch")?.as_f64()? < 0.0 {
            bail!("levels[{i}].mean_batch must be >= 0");
        }
        l.get("batches")?.as_usize()?;
        l.get("rejected")?.as_usize()?;
        l.get("bit_identical_to_b1")?.as_bool()?;
        let lat = l.get("latency")?;
        if lat.get("n")?.as_usize()? == 0 {
            bail!("levels[{i}].latency.n must be >= 1");
        }
        for key in ["mean_us", "min_us", "max_us", "p50_us", "p90_us", "p99_us"] {
            let x = lat.get(key)?.as_f64()?;
            if !x.is_finite() || x < 0.0 {
                bail!("levels[{i}].latency.{key} must be finite and >= 0");
            }
        }
    }
    Ok(())
}

/// Counters a level diffs around its timed window (from a `metrics` job).
#[derive(Clone, Copy, Debug, Default)]
struct Counters {
    batches: usize,
    coalesced: usize,
    rejected: usize,
}

fn counters(engine: &Engine) -> Result<Counters> {
    match engine.submit(JobSpec::Metrics(MetricsJob)).wait()? {
        JobResult::Metrics { data } => Ok(Counters {
            batches: data.get("batches")?.as_usize()?,
            coalesced: data.get("coalesced")?.as_usize()?,
            rejected: data.get("rejected")?.as_usize()?,
        }),
        other => bail!("metrics job returned a {} result", other.kind_name()),
    }
}

/// Run the serve load phase and return the report.
pub fn run_serve_bench(cfg: &ServeBenchConfig) -> Result<ServeReport> {
    run_serve_bench_observed(cfg, &mut NullObserver)
}

/// [`run_serve_bench`] with an observer: one log line per timed level, and
/// a cancellation poll between levels (the job engine's progress feed).
/// Observation is passive — the measured numbers are unchanged.
pub fn run_serve_bench_observed(
    cfg: &ServeBenchConfig,
    obs: &mut dyn Observer,
) -> Result<ServeReport> {
    if cfg.max_batch_levels.is_empty() {
        bail!("serve bench needs at least one max_batch level");
    }
    let clients = cfg.clients.max(1);
    let requests = cfg.requests.max(1);
    let test_n = cfg.test_n.max(1);

    // One synthetic warm model shared (Arc) by every level's engine: the
    // phase measures serving, not checkpoint IO, so the registry entry is
    // built directly — same seam a `load` job fills.
    let variant = builtin_variant(&cfg.variant)
        .ok_or_else(|| anyhow!("serve bench needs a native built-in variant, not '{}'", cfg.variant))?;
    let params = variant.param_count;
    let state = Arc::new(ModelState::init(&variant, &InitConfig { dirac: true, seed: 0 }));
    let content_hash = state_md5(&state);
    let core = Arc::new(NativeShared::new(variant));

    let mut levels: Vec<ServeLevel> = Vec::with_capacity(cfg.max_batch_levels.len());
    let mut baseline: Option<(f64, Vec<String>)> = None; // (wall_s, md5s) of levels[0]
    for &max_batch in &cfg.max_batch_levels {
        if obs.cancelled() {
            return Err(Cancelled.into());
        }
        let engine = Engine::new(EngineConfig {
            job_slots: 1,
            batcher: BatcherConfig {
                max_batch,
                max_wait_us: cfg.max_wait_us,
                queue_cap: cfg.queue_cap,
                kernel_threads: 0,
            },
            ..EngineConfig::default()
        });
        engine.registry().insert(WarmModel {
            id: "bench".into(),
            content_hash: content_hash.clone(),
            variant_name: cfg.variant.clone(),
            params,
            path: PathBuf::from("synthetic"),
            config: Json::Null,
            seed: String::new(),
            state: Arc::clone(&state),
            shared: Arc::clone(&core),
        });
        let spec = |index: usize| {
            JobSpec::PredictOne(PredictOneJob {
                model: "bench".into(),
                index,
                data: DataKind::Cifar10,
                test_n: Some(test_n),
            })
        };
        // Untimed warmup: batcher thread spawn, dataset generation, first
        // touch of the eval plan — §3.7 applied to serving.
        engine.submit(spec(0)).wait().context("serve warmup request")?;

        let before = counters(&engine)?;
        let t0 = Instant::now();
        let per_client: Vec<Result<(Histogram, Vec<String>)>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    let engine = &engine;
                    let spec = &spec;
                    s.spawn(move || -> Result<(Histogram, Vec<String>)> {
                        let mut hist = Histogram::new();
                        let mut md5s = Vec::with_capacity(requests);
                        for r in 0..requests {
                            let index = (c * requests + r) % test_n;
                            let result =
                                engine.submit_from(c as u64 + 1, spec(index)).wait()?;
                            match result {
                                JobResult::PredictOne { probs_md5, latency_us, .. } => {
                                    hist.record(latency_us);
                                    md5s.push(probs_md5);
                                }
                                other => {
                                    bail!("predict_one returned a {} result", other.kind_name())
                                }
                            }
                        }
                        Ok((hist, md5s))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("serve client thread panicked"))
                .collect()
        });
        let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
        let after = counters(&engine)?;

        let mut latency = Histogram::new();
        let mut md5s: Vec<String> = Vec::with_capacity(clients * requests);
        for r in per_client {
            let (h, m) = r?;
            latency.merge(&h);
            md5s.extend(m);
        }
        let batches = after.batches.saturating_sub(before.batches);
        let coalesced = after.coalesced.saturating_sub(before.coalesced);
        let total = clients * requests;
        let (base_wall, bit_identical) = match &baseline {
            None => (wall_s, true),
            Some((w0, m0)) => (*w0, *m0 == md5s),
        };
        if baseline.is_none() {
            baseline = Some((wall_s, md5s));
        }
        let level = ServeLevel {
            max_batch,
            wall_s,
            req_per_s: total as f64 / wall_s,
            batches,
            mean_batch: if batches > 0 { coalesced as f64 / batches as f64 } else { 0.0 },
            rejected: after.rejected.saturating_sub(before.rejected),
            latency,
            speedup_vs_b1: base_wall / wall_s,
            bit_identical_to_b1: bit_identical,
        };
        obs.on_log(&format!(
            "[bench] serve level max_batch={max_batch} done in {wall_s:.2}s \
             ({:.0} req/s, mean batch {:.2}, p99 {:.0}µs)",
            level.req_per_s,
            level.mean_batch,
            level.latency.quantile(0.99),
        ));
        levels.push(level);
    }
    let mut effective = cfg.clone();
    effective.clients = clients;
    effective.requests = requests;
    effective.test_n = test_n;
    Ok(ServeReport {
        tag: cfg.tag.clone().unwrap_or_else(|| "native_serve".into()),
        backend_name: "native".into(),
        variant: cfg.variant.clone(),
        cores: available_cores(),
        config: effective,
        levels,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn minimal_doc(schema: &str, wall: f64) -> Json {
        let lat = r#"{"n": 4, "mean_us": 100.0, "min_us": 50.0, "max_us": 200.0,
                      "p50_us": 100.0, "p90_us": 180.0, "p99_us": 200.0}"#;
        let s = format!(
            r#"{{
              "schema": "{schema}", "tag": "t", "backend": "native", "variant": "nano",
              "created_unix": 0,
              "protocol": {{"clients": 2, "requests_per_client": 2,
                            "max_batch_levels": [1], "max_wait_us": 2000,
                            "queue_cap": 256, "test_n": 4, "data": "synthetic-cifar"}},
              "env": {{"cores": 4, "os": "linux", "arch": "x86_64"}},
              "levels": [{{"max_batch": 1, "wall_s": {wall}, "req_per_s": 4.0,
                           "batches": 4, "mean_batch": 1.0, "rejected": 0,
                           "latency": {lat},
                           "speedup_vs_b1": 1.0, "bit_identical_to_b1": true}}]
            }}"#
        );
        parse(&s).unwrap()
    }

    #[test]
    fn validate_serve_accepts_minimal_and_rejects_damage() {
        validate_serve(&minimal_doc(SERVE_SCHEMA, 1.0)).unwrap();
        assert!(validate_serve(&minimal_doc("airbench.bench/2", 1.0)).is_err());
        assert!(validate_serve(&minimal_doc(SERVE_SCHEMA, 0.0)).is_err());
        assert!(validate_serve(&parse("{}").unwrap()).is_err());
    }

    // run_serve_bench itself is covered end-to-end (tiny protocol) by
    // tests/serve_batch.rs — it needs a compiled engine.
}
