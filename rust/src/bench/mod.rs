//! The persistent benchmark harness: the paper's §3.7 timing protocol
//! ("warm up once, then time many runs") against any [`Backend`], with
//! per-phase medians and seed-distribution statistics, written as
//! machine-readable `BENCH_<tag>.json` at the repository root so every PR
//! appends a comparable point to the perf trajectory (BENCHMARKS.md).
//!
//! Two measurement granularities per run seed:
//!
//! * **micro** — `steps` individually-timed train steps on a fixed batch
//!   (reported as the per-run *median* step time, so one descheduling
//!   hiccup cannot move the number), plus the init phase (state init +
//!   whitening statistics) and one full TTA evaluation;
//! * **macro** — one complete training run through
//!   [`crate::coordinator::train_full`], broken into the paper-protocol
//!   phases via [`PhaseTimes`].
//!
//! Each metric is reported as a distribution over `runs` seeds
//! (mean/std/min/max/median + raw per-run values): run-to-run variance is
//! real (Picard, arXiv 2109.08203) and a single-run number would regularly
//! mislead by more than the effects we tune for. Everything runs on the
//! deterministic synthetic CIFAR proxy, so the harness needs no artifacts,
//! no downloads, and produces comparable numbers on any machine.

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::trainer::PhaseTimes;
use crate::coordinator::{evaluate, train_full, warmup};
use crate::data::synthetic::{cifar_like, SynthConfig};
use crate::runtime::{create_default_backend, Backend, BackendKind, InitConfig};
use crate::stats::basic::Summary;
use crate::util::json::Json;

/// Schema identifier written into (and required from) every `BENCH_*.json`.
pub const SCHEMA: &str = "airbench.bench/1";

/// Harness configuration (CLI: `airbench bench [--runs N] [--steps N] ...`).
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Variant to execute (built-in native table or AOT manifest).
    pub variant: String,
    /// Backend selection; `Auto` resolves exactly like the trainer.
    pub backend: BackendKind,
    /// Tag for the output file name `BENCH_<tag>.json`; defaults to
    /// `<backend>_<variant>` of the backend actually constructed.
    pub tag: Option<String>,
    /// Untimed warmup runs before any measurement (§3.7: compilation and
    /// one-time lazy costs are paid here).
    pub warmup_runs: usize,
    /// Timed runs; run `r` uses seed `r` (the seed distribution).
    pub runs: usize,
    /// Individually-timed train steps per run in the micro phase.
    pub steps: usize,
    /// Epochs of the macro (full-run) phase.
    pub epochs: f64,
    /// Synthetic training-set size (clamped up to two train batches).
    pub train_n: usize,
    /// Synthetic test-set size (clamped up to one eval batch).
    pub test_n: usize,
    /// Data-pipeline workers for the macro phase (0 = synchronous).
    pub workers: usize,
    /// Directory the JSON report is written to (repo root by convention).
    pub out_dir: PathBuf,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            variant: "bench".into(),
            backend: BackendKind::Auto,
            tag: None,
            warmup_runs: 1,
            runs: 5,
            steps: 30,
            epochs: 1.0,
            train_n: 2048,
            test_n: 512,
            workers: 0,
            out_dir: PathBuf::from("."),
        }
    }
}

/// One metric's distribution over the run seeds.
#[derive(Clone, Debug, Default)]
pub struct Dist {
    /// Raw per-run values, in run (= seed) order.
    pub per_run: Vec<f64>,
}

impl Dist {
    /// Mean/std/min/max over the runs.
    pub fn summary(&self) -> Summary {
        Summary::of(&self.per_run)
    }

    /// Median over the runs (the headline number of every phase).
    pub fn median(&self) -> f64 {
        if self.per_run.is_empty() {
            return 0.0;
        }
        let mut v = self.per_run.clone();
        v.sort_by(f64::total_cmp);
        let n = v.len();
        if n % 2 == 1 {
            v[n / 2]
        } else {
            0.5 * (v[n / 2 - 1] + v[n / 2])
        }
    }

    fn push(&mut self, x: f64) {
        self.per_run.push(x);
    }

    fn to_json(&self) -> Json {
        let s = self.summary();
        Json::obj(vec![
            ("n", Json::num(s.n as f64)),
            ("mean", Json::num(s.mean)),
            ("std", Json::num(s.std)),
            ("min", Json::num(s.min)),
            ("max", Json::num(s.max)),
            ("median", Json::num(self.median())),
            (
                "per_run",
                Json::Arr(self.per_run.iter().map(|&x| Json::num(x)).collect()),
            ),
        ])
    }
}

/// Everything one harness invocation measured.
#[derive(Clone, Debug)]
pub struct Report {
    /// File tag (`BENCH_<tag>.json`).
    pub tag: String,
    /// Name of the backend actually constructed (`"native"` / `"pjrt"`).
    pub backend_name: String,
    /// Variant executed.
    pub variant: String,
    /// Train batch size of the variant.
    pub batch_train: usize,
    /// Protocol knobs, echoed for reproducibility.
    pub config: BenchConfig,
    /// Native kernel threads in effect during the measurement (0 when the
    /// measured backend is not the native one — the knob does not apply).
    pub threads: usize,
    /// Micro phase: per-run *median* train-step milliseconds.
    pub step_ms: Dist,
    /// Micro phase: state init + whitening milliseconds.
    pub init_ms: Dist,
    /// Micro phase: one full TTA evaluation, milliseconds.
    pub eval_ms: Dist,
    /// Macro phase: paper-protocol full-run seconds.
    pub run_s: Dist,
    /// Macro phase: step-loop share of the run, seconds.
    pub run_train_s: Dist,
    /// Macro phase: final-eval share of the run, seconds.
    pub run_eval_s: Dist,
    /// Macro phase: final accuracy per run (sanity floor, not a perf metric).
    pub run_acc: Dist,
    /// Analytic FLOPs of one train step (3x forward rule).
    pub flops_per_step: f64,
    /// Cumulative backend accounting over the whole harness invocation.
    pub stats: crate::runtime::BackendStats,
}

impl Report {
    /// Effective GFLOP/s of the median micro train step.
    pub fn train_gflops(&self) -> f64 {
        let ms = self.step_ms.median();
        if ms > 0.0 {
            self.flops_per_step / (ms * 1e-3) / 1e9
        } else {
            0.0
        }
    }

    /// The machine-readable report (schema documented in BENCHMARKS.md).
    pub fn to_json(&self) -> Json {
        let c = &self.config;
        let seeds: Vec<Json> = (0..c.runs).map(|r| Json::num(r as f64)).collect();
        Json::obj(vec![
            ("schema", Json::str(SCHEMA)),
            ("tag", Json::str(&self.tag)),
            ("backend", Json::str(&self.backend_name)),
            ("variant", Json::str(&self.variant)),
            (
                "created_unix",
                Json::num(
                    std::time::SystemTime::now()
                        .duration_since(std::time::UNIX_EPOCH)
                        .map(|d| d.as_secs() as f64)
                        .unwrap_or(0.0),
                ),
            ),
            (
                "protocol",
                Json::obj(vec![
                    ("warmup_runs", Json::num(c.warmup_runs as f64)),
                    ("runs", Json::num(c.runs as f64)),
                    ("seeds", Json::Arr(seeds)),
                    ("steps_per_run", Json::num(c.steps as f64)),
                    ("epochs", Json::num(c.epochs)),
                    ("train_n", Json::num(c.train_n as f64)),
                    ("test_n", Json::num(c.test_n as f64)),
                    ("batch_train", Json::num(self.batch_train as f64)),
                    ("data", Json::str("synthetic-cifar")),
                ]),
            ),
            (
                "env",
                Json::obj(vec![
                    ("threads", Json::num(self.threads as f64)),
                    ("workers", Json::num(c.workers as f64)),
                    ("os", Json::str(std::env::consts::OS)),
                    ("arch", Json::str(std::env::consts::ARCH)),
                ]),
            ),
            (
                "phases",
                Json::obj(vec![
                    ("train_step_ms", self.step_ms.to_json()),
                    ("init_ms", self.init_ms.to_json()),
                    ("eval_ms", self.eval_ms.to_json()),
                    ("run_s", self.run_s.to_json()),
                    ("run_train_s", self.run_train_s.to_json()),
                    ("run_eval_s", self.run_eval_s.to_json()),
                    ("run_acc", self.run_acc.to_json()),
                ]),
            ),
            (
                "derived",
                Json::obj(vec![
                    ("flops_per_step", Json::num(self.flops_per_step)),
                    ("train_gflops", Json::num(self.train_gflops())),
                    (
                        "train_img_per_s",
                        Json::num(if self.step_ms.median() > 0.0 {
                            self.batch_train as f64 / (self.step_ms.median() * 1e-3)
                        } else {
                            0.0
                        }),
                    ),
                ]),
            ),
            (
                "backend_stats",
                Json::obj(vec![
                    ("train_steps", Json::num(self.stats.train_steps as f64)),
                    ("eval_calls", Json::num(self.stats.eval_calls as f64)),
                    ("train_exec_secs", Json::num(self.stats.train_exec_secs)),
                    ("train_marshal_secs", Json::num(self.stats.train_marshal_secs)),
                    ("eval_exec_secs", Json::num(self.stats.eval_exec_secs)),
                    ("eval_marshal_secs", Json::num(self.stats.eval_marshal_secs)),
                    ("compile_secs", Json::num(self.stats.compile_secs)),
                    (
                        "train_marshal_share",
                        Json::num(self.stats.train_marshal_share()),
                    ),
                    ("eval_marshal_share", Json::num(self.stats.eval_marshal_share())),
                ]),
            ),
        ])
    }

    /// Write `BENCH_<tag>.json` into `dir`; returns the path. The emitted
    /// document is validated against the schema before writing, so a
    /// harness bug cannot poison the committed trajectory.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        let j = self.to_json();
        validate(&j).context("harness produced a schema-invalid report")?;
        let path = dir.join(format!("BENCH_{}.json", self.tag));
        std::fs::write(&path, j.to_pretty_string())
            .with_context(|| format!("writing {path:?}"))?;
        Ok(path)
    }
}

/// Validate a `BENCH_*.json` document against the [`SCHEMA`] contract:
/// required keys, types, and per-phase distribution consistency
/// (`per_run.len() == n`, all values finite). Used by the harness before
/// writing and by the schema smoke test on committed baselines.
pub fn validate(j: &Json) -> Result<()> {
    let schema = j.get("schema")?.as_str()?;
    if schema != SCHEMA {
        bail!("unknown bench schema '{schema}' (want '{SCHEMA}')");
    }
    for key in ["tag", "backend", "variant"] {
        let s = j.get(key)?.as_str()?;
        if s.is_empty() {
            bail!("'{key}' must be a non-empty string");
        }
    }
    j.get("created_unix")?.as_f64()?;
    let proto = j.get("protocol")?;
    let runs = proto.get("runs")?.as_usize()?;
    if runs == 0 {
        bail!("protocol.runs must be >= 1");
    }
    if proto.get("seeds")?.as_arr()?.len() != runs {
        bail!("protocol.seeds length must equal protocol.runs");
    }
    for key in ["warmup_runs", "steps_per_run", "train_n", "test_n", "batch_train"] {
        proto.get(key)?.as_f64()?;
    }
    let env = j.get("env")?;
    env.get("threads")?.as_usize()?;
    env.get("os")?.as_str()?;
    env.get("arch")?.as_str()?;
    let phases = j.get("phases")?.as_obj()?;
    for key in [
        "train_step_ms",
        "init_ms",
        "eval_ms",
        "run_s",
        "run_train_s",
        "run_eval_s",
        "run_acc",
    ] {
        let d = phases
            .get(key)
            .with_context(|| format!("missing phase '{key}'"))?;
        let n = d.get("n")?.as_usize()?;
        if n != runs {
            bail!("phase '{key}': n {n} != protocol.runs {runs}");
        }
        let per_run = d.get("per_run")?.as_arr()?;
        if per_run.len() != n {
            bail!("phase '{key}': per_run length {} != n {n}", per_run.len());
        }
        for stat in ["mean", "std", "min", "max", "median"] {
            let x = d.get(stat)?.as_f64()?;
            if !x.is_finite() {
                bail!("phase '{key}': {stat} is not finite");
            }
        }
        for v in per_run {
            if !v.as_f64()?.is_finite() {
                bail!("phase '{key}': non-finite per_run entry");
            }
        }
    }
    let derived = j.get("derived")?;
    derived.get("train_gflops")?.as_f64()?;
    let bs = j.get("backend_stats")?;
    for key in ["train_steps", "train_exec_secs", "compile_secs"] {
        bs.get(key)?.as_f64()?;
    }
    Ok(())
}

/// Run the full protocol described by `cfg` and return the report (the
/// caller decides whether to [`Report::write`] it).
pub fn run(cfg: &BenchConfig) -> Result<Report> {
    let mut engine = create_default_backend(cfg.backend, &cfg.variant)?;
    let engine = engine.as_mut();
    let batch = engine.batch_train();
    let hw = engine.variant().image_hw;
    let train_n = cfg.train_n.max(2 * batch);
    let test_n = cfg.test_n.max(engine.batch_eval());
    // Generated at the variant's resolution, so the micro-phase batch copy
    // below can never silently mismatch.
    let synth = |n: usize| SynthConfig { n, hw, ..SynthConfig::default() };
    let train_ds = cifar_like(&synth(train_n), 0xBE9C, 0);
    let test_ds = cifar_like(&synth(test_n), 0xBE9C, 1);
    let whiten_samples = train_n.min(1024);

    let base_cfg = TrainConfig {
        variant: cfg.variant.to_string(),
        epochs: cfg.epochs,
        workers: cfg.workers,
        whiten_samples,
        eval_every_epoch: false,
        ..TrainConfig::default()
    };

    // §3.7: pay every one-time cost before the clock starts.
    for _ in 0..cfg.warmup_runs {
        warmup(engine, &train_ds, &base_cfg)?;
    }

    let mut report = Report {
        tag: cfg
            .tag
            .clone()
            .unwrap_or_else(|| format!("{}_{}", engine.name(), cfg.variant)),
        backend_name: engine.name().to_string(),
        variant: cfg.variant.clone(),
        batch_train: batch,
        config: cfg.clone(),
        threads: if engine.name() == "native" {
            crate::runtime::native::default_threads()
        } else {
            0
        },
        step_ms: Dist::default(),
        init_ms: Dist::default(),
        eval_ms: Dist::default(),
        run_s: Dist::default(),
        run_train_s: Dist::default(),
        run_eval_s: Dist::default(),
        run_acc: Dist::default(),
        flops_per_step: engine.variant().train_flops_per_example() as f64 * batch as f64,
        stats: *engine.stats(),
    };

    // A fixed training batch for the micro phase (augmentation excluded:
    // this phase isolates backend step time; the macro phase covers the
    // full pipeline). copy_from_slice panics loudly on any size mismatch —
    // a degenerate all-zero batch must never be silently timed.
    let mut images = crate::tensor::Tensor::zeros(&[batch, 3, hw, hw]);
    for i in 0..batch {
        images
            .image_mut(i)
            .copy_from_slice(train_ds.images.image(i % train_ds.len()));
    }
    let labels: Vec<i32> = (0..batch)
        .map(|i| train_ds.labels[i % train_ds.len()] as i32)
        .collect();

    for run in 0..cfg.runs {
        let seed = run as u64;
        // ---- micro: init phase (state init + whitening stats) ----------
        let t0 = Instant::now();
        let mut state = engine.init_state(&InitConfig { dirac: true, seed });
        let head = train_ds.head(whiten_samples);
        let wk = engine.variant().hyper.whiten_kernel;
        state.set_whitening(crate::whitening::whitening_weights(
            &head.images,
            wk,
            base_cfg.whiten_eps,
        )?)?;
        report.init_ms.push(t0.elapsed().as_secs_f64() * 1e3);

        // ---- micro: per-step medians ------------------------------------
        let mut samples = Vec::with_capacity(cfg.steps);
        for _ in 0..cfg.steps {
            let t0 = Instant::now();
            engine.train_step(&mut state, &images, &labels, 1e-3, 0.1, true)?;
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        // Same median definition as the cross-run Dist reporting (even
        // counts average the two middle samples).
        report.step_ms.push(Dist { per_run: samples }.median());

        // ---- micro: one full TTA evaluation -----------------------------
        let t0 = Instant::now();
        let _ = evaluate(engine, &state, &test_ds, base_cfg.tta)?;
        report.eval_ms.push(t0.elapsed().as_secs_f64() * 1e3);

        // ---- macro: one paper-protocol run ------------------------------
        let run_cfg = TrainConfig { seed, ..base_cfg.clone() };
        let (result, _state) = train_full(engine, &train_ds, &test_ds, &run_cfg)?;
        let PhaseTimes { setup_seconds: _, train_seconds, eval_seconds } = result.phases;
        report.run_s.push(result.time_seconds);
        report.run_train_s.push(train_seconds);
        report.run_eval_s.push(eval_seconds);
        report.run_acc.push(result.accuracy);
    }
    report.stats = *engine.stats();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_median_and_summary() {
        let d = Dist { per_run: vec![3.0, 1.0, 2.0] };
        assert_eq!(d.median(), 2.0);
        let e = Dist { per_run: vec![4.0, 1.0, 2.0, 3.0] };
        assert_eq!(e.median(), 2.5);
        assert_eq!(Dist::default().median(), 0.0);
        assert_eq!(d.summary().n, 3);
    }

    #[test]
    fn validate_rejects_broken_documents() {
        use crate::util::json::parse;
        // A minimal valid skeleton is exercised end-to-end by
        // tests/bench_harness.rs; here: the validator must fail loudly on
        // structural damage.
        assert!(validate(&parse("{}").unwrap()).is_err());
        assert!(validate(&parse(r#"{"schema": "nope"}"#).unwrap()).is_err());
    }
}
