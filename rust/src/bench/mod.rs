//! The persistent benchmark harness: the paper's §3.7 timing protocol
//! ("warm up once, then time many runs") against any [`Backend`], with
//! per-phase medians and seed-distribution statistics, written as
//! machine-readable `BENCH_<tag>.json` at the repository root so every PR
//! appends a comparable point to the perf trajectory (BENCHMARKS.md).
//!
//! Two measurement granularities per run seed:
//!
//! * **micro** — `steps` individually-timed train steps on a fixed batch
//!   (reported as the per-run *median* step time, so one descheduling
//!   hiccup cannot move the number), plus the init phase (state init +
//!   whitening statistics) and one full TTA evaluation;
//! * **macro** — one complete training run through
//!   [`crate::coordinator::train_full`], broken into the paper-protocol
//!   phases via [`PhaseTimes`].
//!
//! Each metric is reported as a distribution over `runs` seeds
//! (mean/std/min/max/median + raw per-run values): run-to-run variance is
//! real (Picard, arXiv 2109.08203) and a single-run number would regularly
//! mislead by more than the effects we tune for. Everything runs on the
//! deterministic synthetic CIFAR proxy, so the harness needs no artifacts,
//! no downloads, and produces comparable numbers on any machine.

pub mod serve;

pub use serve::{
    run_serve_bench, run_serve_bench_observed, validate_serve, ServeBenchConfig, ServeLevel,
    ServeReport, SERVE_SCHEMA,
};

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::coordinator::observer::{Cancelled, NullObserver, Observer};
use crate::coordinator::trainer::PhaseTimes;
use crate::coordinator::{evaluate, run_fleet_parallel, train_full, warmup};
use crate::data::synthetic::{cifar_like, SynthConfig};
use crate::runtime::native::available_cores;
use crate::runtime::{create_default_backend, Backend, BackendKind, EngineSpec, InitConfig};
use crate::stats::basic::{Summary, Welford};
use crate::util::json::Json;

/// Schema identifier written into every single-run `BENCH_*.json` (the
/// fleet phase uses [`FLEET_SCHEMA`]). Version 2 adds the `env.kernel` and
/// `env.cpu_features` fields so baselines measured on different ISAs (or
/// different GEMM register tiles) can't be silently compared — see
/// [`comparable`].
pub const SCHEMA: &str = "airbench.bench/2";

/// Previous single-run schema (PR 3–PR 6 baselines). Still validated so
/// committed history stays checkable; [`comparable`] treats its missing
/// kernel field as "unknown" and refuses cross-version perf comparison.
pub const SCHEMA_V1: &str = "airbench.bench/1";

/// Schema identifier of fleet-throughput reports (`airbench bench --fleet`).
pub const FLEET_SCHEMA: &str = "airbench.fleet-bench/1";

/// Harness configuration (CLI: `airbench bench [--runs N] [--steps N] ...`).
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Variant to execute (built-in native table or AOT manifest).
    pub variant: String,
    /// Backend selection; `Auto` resolves exactly like the trainer.
    pub backend: BackendKind,
    /// Tag for the output file name `BENCH_<tag>.json`; defaults to
    /// `<backend>_<variant>` of the backend actually constructed.
    pub tag: Option<String>,
    /// Untimed warmup runs before any measurement (§3.7: compilation and
    /// one-time lazy costs are paid here).
    pub warmup_runs: usize,
    /// Timed runs; run `r` uses seed `r` (the seed distribution).
    pub runs: usize,
    /// Individually-timed train steps per run in the micro phase.
    pub steps: usize,
    /// Epochs of the macro (full-run) phase.
    pub epochs: f64,
    /// Synthetic training-set size (clamped up to two train batches).
    pub train_n: usize,
    /// Synthetic test-set size (clamped up to one eval batch).
    pub test_n: usize,
    /// Data-pipeline workers for the macro phase (0 = synchronous).
    pub workers: usize,
    /// Directory the JSON report is written to (repo root by convention).
    pub out_dir: PathBuf,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            variant: "bench".into(),
            backend: BackendKind::Auto,
            tag: None,
            warmup_runs: 1,
            runs: 5,
            steps: 30,
            epochs: 1.0,
            train_n: 2048,
            test_n: 512,
            workers: 0,
            out_dir: PathBuf::from("."),
        }
    }
}

/// One metric's distribution over the run seeds.
#[derive(Clone, Debug, Default)]
pub struct Dist {
    /// Raw per-run values, in run (= seed) order.
    pub per_run: Vec<f64>,
}

impl Dist {
    /// Mean/std/min/max over the runs.
    pub fn summary(&self) -> Summary {
        Summary::of(&self.per_run)
    }

    /// Median over the runs (the headline number of every phase).
    pub fn median(&self) -> f64 {
        if self.per_run.is_empty() {
            return 0.0;
        }
        let mut v = self.per_run.clone();
        v.sort_by(f64::total_cmp);
        let n = v.len();
        if n % 2 == 1 {
            v[n / 2]
        } else {
            0.5 * (v[n / 2 - 1] + v[n / 2])
        }
    }

    fn push(&mut self, x: f64) {
        self.per_run.push(x);
    }

    fn to_json(&self) -> Json {
        let s = self.summary();
        Json::obj(vec![
            ("n", Json::num(s.n as f64)),
            ("mean", Json::num(s.mean)),
            ("std", Json::num(s.std)),
            ("min", Json::num(s.min)),
            ("max", Json::num(s.max)),
            ("median", Json::num(self.median())),
            (
                "per_run",
                Json::Arr(self.per_run.iter().map(|&x| Json::num(x)).collect()),
            ),
        ])
    }
}

/// Everything one harness invocation measured.
#[derive(Clone, Debug)]
pub struct Report {
    /// File tag (`BENCH_<tag>.json`).
    pub tag: String,
    /// Name of the backend actually constructed (`"native"` / `"pjrt"`).
    pub backend_name: String,
    /// Variant executed.
    pub variant: String,
    /// Train batch size of the variant.
    pub batch_train: usize,
    /// Protocol knobs, echoed for reproducibility.
    pub config: BenchConfig,
    /// Kernel threads the measured backend actually used (reported by
    /// [`Backend::kernel_threads`]; 0 when the knob does not apply — PJRT
    /// owns its own threading).
    pub threads: usize,
    /// GEMM register tile the measured backend ran ([`Backend::kernel_name`];
    /// `"-"` for backends without a dispatchable kernel).
    pub kernel: String,
    /// SIMD features detected on the measuring CPU (empty on non-x86).
    pub cpu_features: Vec<String>,
    /// Micro phase: per-run *median* train-step milliseconds.
    pub step_ms: Dist,
    /// Micro phase: state init + whitening milliseconds.
    pub init_ms: Dist,
    /// Micro phase: one full TTA evaluation, milliseconds.
    pub eval_ms: Dist,
    /// Macro phase: paper-protocol full-run seconds.
    pub run_s: Dist,
    /// Macro phase: step-loop share of the run, seconds.
    pub run_train_s: Dist,
    /// Macro phase: final-eval share of the run, seconds.
    pub run_eval_s: Dist,
    /// Macro phase: final accuracy per run (sanity floor, not a perf metric).
    pub run_acc: Dist,
    /// Analytic FLOPs of one train step (3x forward rule).
    pub flops_per_step: f64,
    /// Cumulative backend accounting over the whole harness invocation.
    pub stats: crate::runtime::BackendStats,
}

impl Report {
    /// Effective GFLOP/s of the median micro train step.
    pub fn train_gflops(&self) -> f64 {
        let ms = self.step_ms.median();
        if ms > 0.0 {
            self.flops_per_step / (ms * 1e-3) / 1e9
        } else {
            0.0
        }
    }

    /// The machine-readable report (schema documented in BENCHMARKS.md).
    pub fn to_json(&self) -> Json {
        let c = &self.config;
        let seeds: Vec<Json> = (0..c.runs).map(|r| Json::num(r as f64)).collect();
        Json::obj(vec![
            ("schema", Json::str(SCHEMA)),
            ("tag", Json::str(&self.tag)),
            ("backend", Json::str(&self.backend_name)),
            ("variant", Json::str(&self.variant)),
            (
                "created_unix",
                Json::num(
                    std::time::SystemTime::now()
                        .duration_since(std::time::UNIX_EPOCH)
                        .map(|d| d.as_secs() as f64)
                        .unwrap_or(0.0),
                ),
            ),
            (
                "protocol",
                Json::obj(vec![
                    ("warmup_runs", Json::num(c.warmup_runs as f64)),
                    ("runs", Json::num(c.runs as f64)),
                    ("seeds", Json::Arr(seeds)),
                    ("steps_per_run", Json::num(c.steps as f64)),
                    ("epochs", Json::num(c.epochs)),
                    ("train_n", Json::num(c.train_n as f64)),
                    ("test_n", Json::num(c.test_n as f64)),
                    ("batch_train", Json::num(self.batch_train as f64)),
                    ("data", Json::str("synthetic-cifar")),
                ]),
            ),
            (
                "env",
                Json::obj(vec![
                    ("threads", Json::num(self.threads as f64)),
                    ("workers", Json::num(c.workers as f64)),
                    ("os", Json::str(std::env::consts::OS)),
                    ("arch", Json::str(std::env::consts::ARCH)),
                    ("kernel", Json::str(&self.kernel)),
                    (
                        "cpu_features",
                        Json::Arr(self.cpu_features.iter().map(|f| Json::str(f)).collect()),
                    ),
                ]),
            ),
            (
                "phases",
                Json::obj(vec![
                    ("train_step_ms", self.step_ms.to_json()),
                    ("init_ms", self.init_ms.to_json()),
                    ("eval_ms", self.eval_ms.to_json()),
                    ("run_s", self.run_s.to_json()),
                    ("run_train_s", self.run_train_s.to_json()),
                    ("run_eval_s", self.run_eval_s.to_json()),
                    ("run_acc", self.run_acc.to_json()),
                ]),
            ),
            (
                "derived",
                Json::obj(vec![
                    ("flops_per_step", Json::num(self.flops_per_step)),
                    ("train_gflops", Json::num(self.train_gflops())),
                    (
                        "train_img_per_s",
                        Json::num(if self.step_ms.median() > 0.0 {
                            self.batch_train as f64 / (self.step_ms.median() * 1e-3)
                        } else {
                            0.0
                        }),
                    ),
                ]),
            ),
            (
                "backend_stats",
                Json::obj(vec![
                    ("train_steps", Json::num(self.stats.train_steps as f64)),
                    ("eval_calls", Json::num(self.stats.eval_calls as f64)),
                    ("train_exec_secs", Json::num(self.stats.train_exec_secs)),
                    ("train_marshal_secs", Json::num(self.stats.train_marshal_secs)),
                    ("eval_exec_secs", Json::num(self.stats.eval_exec_secs)),
                    ("eval_marshal_secs", Json::num(self.stats.eval_marshal_secs)),
                    ("compile_secs", Json::num(self.stats.compile_secs)),
                    (
                        "train_marshal_share",
                        Json::num(self.stats.train_marshal_share()),
                    ),
                    ("eval_marshal_share", Json::num(self.stats.eval_marshal_share())),
                ]),
            ),
        ])
    }

    /// Write `BENCH_<tag>.json` into `dir`; returns the path. The emitted
    /// document is validated against the schema before writing, so a
    /// harness bug cannot poison the committed trajectory.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        let j = self.to_json();
        validate(&j).context("harness produced a schema-invalid report")?;
        let path = dir.join(format!("BENCH_{}.json", self.tag));
        std::fs::write(&path, j.to_pretty_string())
            .with_context(|| format!("writing {path:?}"))?;
        Ok(path)
    }
}

/// Validate a `BENCH_*.json` document against the [`SCHEMA`] contract:
/// required keys, types, and per-phase distribution consistency
/// (`per_run.len() == n`, all values finite). Used by the harness before
/// writing and by the schema smoke test on committed baselines.
pub fn validate(j: &Json) -> Result<()> {
    let schema = j.get("schema")?.as_str()?;
    if schema != SCHEMA && schema != SCHEMA_V1 {
        bail!("unknown bench schema '{schema}' (want '{SCHEMA}' or '{SCHEMA_V1}')");
    }
    for key in ["tag", "backend", "variant"] {
        let s = j.get(key)?.as_str()?;
        if s.is_empty() {
            bail!("'{key}' must be a non-empty string");
        }
    }
    j.get("created_unix")?.as_f64()?;
    let proto = j.get("protocol")?;
    let runs = proto.get("runs")?.as_usize()?;
    if runs == 0 {
        bail!("protocol.runs must be >= 1");
    }
    if proto.get("seeds")?.as_arr()?.len() != runs {
        bail!("protocol.seeds length must equal protocol.runs");
    }
    for key in ["warmup_runs", "steps_per_run", "train_n", "test_n", "batch_train"] {
        proto.get(key)?.as_f64()?;
    }
    let env = j.get("env")?;
    env.get("threads")?.as_usize()?;
    env.get("os")?.as_str()?;
    env.get("arch")?.as_str()?;
    if schema == SCHEMA {
        // v2: the measuring ISA must be on the record.
        if env.get("kernel")?.as_str()?.is_empty() {
            bail!("env.kernel must be a non-empty string (v2)");
        }
        for f in env.get("cpu_features")?.as_arr()? {
            f.as_str()?;
        }
    }
    let phases = j.get("phases")?.as_obj()?;
    for key in [
        "train_step_ms",
        "init_ms",
        "eval_ms",
        "run_s",
        "run_train_s",
        "run_eval_s",
        "run_acc",
    ] {
        let d = phases
            .get(key)
            .with_context(|| format!("missing phase '{key}'"))?;
        let n = d.get("n")?.as_usize()?;
        if n != runs {
            bail!("phase '{key}': n {n} != protocol.runs {runs}");
        }
        let per_run = d.get("per_run")?.as_arr()?;
        if per_run.len() != n {
            bail!("phase '{key}': per_run length {} != n {n}", per_run.len());
        }
        for stat in ["mean", "std", "min", "max", "median"] {
            let x = d.get(stat)?.as_f64()?;
            if !x.is_finite() {
                bail!("phase '{key}': {stat} is not finite");
            }
        }
        for v in per_run {
            if !v.as_f64()?.is_finite() {
                bail!("phase '{key}': non-finite per_run entry");
            }
        }
    }
    let derived = j.get("derived")?;
    derived.get("train_gflops")?.as_f64()?;
    let bs = j.get("backend_stats")?;
    for key in ["train_steps", "train_exec_secs", "compile_secs"] {
        bs.get(key)?.as_f64()?;
    }
    Ok(())
}

/// Whether two single-run bench reports are a fair perf comparison: same
/// backend, same variant, same arch, and — when both documents record one
/// (schema v2) — the same GEMM kernel. A v1 document's kernel is unknown,
/// so v1-vs-v2 refuses rather than silently comparing a scalar baseline
/// against an AVX2 run. Errors name the mismatched field.
pub fn comparable(a: &Json, b: &Json) -> Result<()> {
    validate(a)?;
    validate(b)?;
    for key in ["backend", "variant"] {
        let (x, y) = (a.get(key)?.as_str()?, b.get(key)?.as_str()?);
        if x != y {
            bail!("reports are not comparable: {key} '{x}' vs '{y}'");
        }
    }
    let (ea, eb) = (a.get("env")?, b.get("env")?);
    let (xa, xb) = (ea.get("arch")?.as_str()?, eb.get("arch")?.as_str()?);
    if xa != xb {
        bail!("reports are not comparable: env.arch '{xa}' vs '{xb}'");
    }
    let kernel = |e: &Json| e.get("kernel").and_then(|k| k.as_str().map(str::to_string)).ok();
    match (kernel(ea), kernel(eb)) {
        (Some(ka), Some(kb)) if ka == kb => Ok(()),
        (Some(ka), Some(kb)) => {
            bail!("reports are not comparable: env.kernel '{ka}' vs '{kb}'")
        }
        _ => bail!(
            "reports are not comparable: at least one predates schema v2 and does \
             not record env.kernel (re-run `airbench bench` to regenerate it)"
        ),
    }
}

// ---------------------------------------------------------------------------
// Fleet-throughput phase (`airbench bench --fleet`)
// ---------------------------------------------------------------------------

/// Configuration of the fleet-throughput phase: the same n-run fleet,
/// timed at each requested `--fleet-parallel` level.
#[derive(Clone, Debug)]
pub struct FleetBenchConfig {
    /// Variant to execute.
    pub variant: String,
    /// Backend selection (parallel levels > 1 need native workers).
    pub backend: BackendKind,
    /// Tag for `BENCH_<tag>.json`; defaults to `<backend>_fleet`.
    pub tag: Option<String>,
    /// Runs per fleet (every level trains the same `n_runs` seeds).
    pub n_runs: usize,
    /// Parallelism levels to time, in order; level `parallel_levels[0]` is
    /// the speedup baseline (conventionally 1).
    pub parallel_levels: Vec<usize>,
    /// Epochs per run.
    pub epochs: f64,
    /// Synthetic training-set size.
    pub train_n: usize,
    /// Synthetic test-set size.
    pub test_n: usize,
    /// Directory the JSON report is written to (repo root by convention).
    pub out_dir: PathBuf,
}

impl Default for FleetBenchConfig {
    fn default() -> Self {
        FleetBenchConfig {
            variant: "nano".into(),
            backend: BackendKind::Auto,
            tag: None,
            n_runs: 8,
            parallel_levels: vec![1, 2, 4],
            epochs: 1.0,
            train_n: 256,
            test_n: 128,
            out_dir: PathBuf::from("."),
        }
    }
}

/// One timed parallelism level of the fleet phase.
#[derive(Clone, Debug)]
pub struct FleetLevel {
    /// Concurrent runs actually executed (the resolved
    /// [`crate::coordinator::fleet::fleet_budget`] — a request beyond
    /// `n_runs` is capped, and a non-parallel backend collapses to 1).
    pub parallel: usize,
    /// Kernel threads each run was budgeted
    /// ([`crate::runtime::ThreadBudget`]).
    pub kernel_threads: usize,
    /// Wall-clock seconds for the whole n-run fleet.
    pub wall_s: f64,
    /// Throughput: `n_runs / wall_s`.
    pub runs_per_s: f64,
    /// `wall_s(levels[0]) / wall_s(this)`.
    pub speedup_vs_p1: f64,
    /// Mean final accuracy across the fleet's runs.
    pub mean_acc: f64,
    /// Whether every per-run accuracy is bit-identical to the first
    /// level's — the scheduler's determinism contract, measured.
    pub bit_identical_to_p1: bool,
}

/// Everything one fleet-phase invocation measured.
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// File tag (`BENCH_<tag>.json`).
    pub tag: String,
    /// Backend actually constructed.
    pub backend_name: String,
    /// Variant executed.
    pub variant: String,
    /// Cores the budget was planned against.
    pub cores: usize,
    /// Protocol knobs, echoed for reproducibility.
    pub config: FleetBenchConfig,
    /// One entry per `parallel_levels` element, in order.
    pub levels: Vec<FleetLevel>,
}

impl FleetReport {
    /// The machine-readable report (schema documented in BENCHMARKS.md).
    pub fn to_json(&self) -> Json {
        let c = &self.config;
        Json::obj(vec![
            ("schema", Json::str(FLEET_SCHEMA)),
            ("tag", Json::str(&self.tag)),
            ("backend", Json::str(&self.backend_name)),
            ("variant", Json::str(&self.variant)),
            (
                "created_unix",
                Json::num(
                    std::time::SystemTime::now()
                        .duration_since(std::time::UNIX_EPOCH)
                        .map(|d| d.as_secs() as f64)
                        .unwrap_or(0.0),
                ),
            ),
            (
                "protocol",
                Json::obj(vec![
                    ("n_runs", Json::num(c.n_runs as f64)),
                    (
                        "parallel_levels",
                        Json::Arr(c.parallel_levels.iter().map(|&p| Json::num(p as f64)).collect()),
                    ),
                    ("epochs", Json::num(c.epochs)),
                    ("train_n", Json::num(c.train_n as f64)),
                    ("test_n", Json::num(c.test_n as f64)),
                    ("data", Json::str("synthetic-cifar")),
                ]),
            ),
            (
                "env",
                Json::obj(vec![
                    ("cores", Json::num(self.cores as f64)),
                    ("os", Json::str(std::env::consts::OS)),
                    ("arch", Json::str(std::env::consts::ARCH)),
                ]),
            ),
            (
                "levels",
                Json::Arr(
                    self.levels
                        .iter()
                        .map(|l| {
                            Json::obj(vec![
                                ("parallel", Json::num(l.parallel as f64)),
                                ("kernel_threads", Json::num(l.kernel_threads as f64)),
                                ("wall_s", Json::num(l.wall_s)),
                                ("runs_per_s", Json::num(l.runs_per_s)),
                                ("speedup_vs_p1", Json::num(l.speedup_vs_p1)),
                                ("mean_acc", Json::num(l.mean_acc)),
                                ("bit_identical_to_p1", Json::Bool(l.bit_identical_to_p1)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Write `BENCH_<tag>.json` into `dir` (schema-validated first).
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        let j = self.to_json();
        validate_fleet(&j).context("fleet phase produced a schema-invalid report")?;
        let path = dir.join(format!("BENCH_{}.json", self.tag));
        std::fs::write(&path, j.to_pretty_string())
            .with_context(|| format!("writing {path:?}"))?;
        Ok(path)
    }
}

/// Validate a fleet-throughput `BENCH_*.json` against [`FLEET_SCHEMA`].
pub fn validate_fleet(j: &Json) -> Result<()> {
    let schema = j.get("schema")?.as_str()?;
    if schema != FLEET_SCHEMA {
        bail!("unknown fleet-bench schema '{schema}' (want '{FLEET_SCHEMA}')");
    }
    for key in ["tag", "backend", "variant"] {
        if j.get(key)?.as_str()?.is_empty() {
            bail!("'{key}' must be a non-empty string");
        }
    }
    j.get("created_unix")?.as_f64()?;
    let proto = j.get("protocol")?;
    let n_runs = proto.get("n_runs")?.as_usize()?;
    if n_runs == 0 {
        bail!("protocol.n_runs must be >= 1");
    }
    let levels_decl = proto.get("parallel_levels")?.as_arr()?.len();
    for key in ["epochs", "train_n", "test_n"] {
        proto.get(key)?.as_f64()?;
    }
    let env = j.get("env")?;
    if env.get("cores")?.as_usize()? == 0 {
        bail!("env.cores must be >= 1");
    }
    env.get("os")?.as_str()?;
    env.get("arch")?.as_str()?;
    let levels = j.get("levels")?.as_arr()?;
    if levels.is_empty() || levels.len() != levels_decl {
        bail!(
            "levels length {} must match protocol.parallel_levels length {levels_decl} (and be >= 1)",
            levels.len()
        );
    }
    for (i, l) in levels.iter().enumerate() {
        if l.get("parallel")?.as_usize()? == 0 || l.get("kernel_threads")?.as_usize()? == 0 {
            bail!("levels[{i}]: parallel and kernel_threads must be >= 1");
        }
        for key in ["wall_s", "runs_per_s", "speedup_vs_p1", "mean_acc"] {
            let x = l.get(key)?.as_f64()?;
            if !x.is_finite() {
                bail!("levels[{i}].{key} is not finite");
            }
        }
        if l.get("wall_s")?.as_f64()? <= 0.0 {
            bail!("levels[{i}].wall_s must be positive");
        }
        l.get("bit_identical_to_p1")?.as_bool()?;
    }
    Ok(())
}

/// Validate any committed report document, dispatching on its `schema`
/// key ([`SCHEMA`], [`FLEET_SCHEMA`], [`SERVE_SCHEMA`], or
/// [`crate::stats::study::SCHEMA`]).
pub fn validate_any(j: &Json) -> Result<()> {
    let schema = j.get("schema")?.as_str()?;
    if schema == FLEET_SCHEMA {
        validate_fleet(j)
    } else if schema == SERVE_SCHEMA {
        validate_serve(j)
    } else if schema == crate::stats::study::SCHEMA {
        crate::stats::study::validate(j)
    } else {
        validate(j)
    }
}

/// Run the fleet-throughput phase: one warmup, then the same `n_runs`-seed
/// fleet timed at every requested parallelism level. Accuracy vectors are
/// compared bitwise across levels — the report records a measured
/// determinism verdict next to the measured speedup.
pub fn run_fleet_bench(cfg: &FleetBenchConfig) -> Result<FleetReport> {
    run_fleet_bench_observed(cfg, &mut NullObserver)
}

/// [`run_fleet_bench`] with an observer: one log line per timed level,
/// and a cancellation poll between levels (the job engine's progress
/// feed). Observation is passive — the measured numbers are unchanged.
pub fn run_fleet_bench_observed(
    cfg: &FleetBenchConfig,
    obs: &mut dyn Observer,
) -> Result<FleetReport> {
    if cfg.parallel_levels.is_empty() {
        bail!("fleet bench needs at least one parallelism level");
    }
    let factory = EngineSpec::new(cfg.backend, &cfg.variant).factory()?;
    let variant = factory.variant().clone();
    let hw = variant.image_hw;
    let train_n = cfg.train_n.max(2 * variant.batch_train);
    let test_n = cfg.test_n.max(variant.batch_eval);
    let synth = |n: usize| SynthConfig { n, hw, ..SynthConfig::default() };
    let train_ds = cifar_like(&synth(train_n), 0xF1E7, 0);
    let test_ds = cifar_like(&synth(test_n), 0xF1E7, 1);

    let run_cfg = TrainConfig {
        variant: cfg.variant.clone(),
        epochs: cfg.epochs,
        whiten_samples: train_n.min(1024),
        eval_every_epoch: false,
        ..TrainConfig::default()
    };

    // §3.7: pay one-time costs (pool spawn, allocators, PJRT compile)
    // untimed. A non-parallel (PJRT) factory keeps this one compiled
    // worker alive across warmup AND every level — spawning per level
    // would put recompilation inside the timed window.
    let mut seq_engine: Option<Box<dyn Backend>> = None;
    {
        let mut w = factory.spawn()?;
        warmup(w.as_mut(), &train_ds, &run_cfg)?;
        if !factory.supports_parallel() {
            seq_engine = Some(w);
        }
    }

    let cores = available_cores();
    let mut levels: Vec<FleetLevel> = Vec::with_capacity(cfg.parallel_levels.len());
    let mut baseline: Option<(f64, Vec<f64>)> = None; // (wall_s, accs) of levels[0]
    for &parallel in &cfg.parallel_levels {
        if obs.cancelled() {
            return Err(Cancelled.into());
        }
        // The budget the scheduler itself resolves — recorded == executed.
        let budget = crate::coordinator::fleet::fleet_budget(&factory, parallel.max(1), cfg.n_runs);
        let t0 = Instant::now();
        let fleet = match seq_engine.as_mut() {
            Some(engine) => crate::coordinator::run_fleet(
                engine.as_mut(),
                &train_ds,
                &test_ds,
                &run_cfg,
                cfg.n_runs,
                None,
            )?,
            None => run_fleet_parallel(
                &factory,
                &train_ds,
                &test_ds,
                &run_cfg,
                cfg.n_runs,
                parallel.max(1),
                None,
            )?,
        };
        let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
        let mut acc = Welford::new();
        for &a in &fleet.accuracies {
            acc.push(a);
        }
        let bit_identical = match &baseline {
            None => true,
            Some((_, accs0)) => {
                accs0.len() == fleet.accuracies.len()
                    && accs0
                        .iter()
                        .zip(&fleet.accuracies)
                        .all(|(a, b)| a.to_bits() == b.to_bits())
            }
        };
        let base_wall = match &baseline {
            None => wall_s,
            Some((w0, _)) => *w0,
        };
        if baseline.is_none() {
            baseline = Some((wall_s, fleet.accuracies.clone()));
        }
        obs.on_log(&format!(
            "[bench] fleet level parallel={} done in {wall_s:.2}s ({:.2} runs/s)",
            budget.runs_parallel,
            cfg.n_runs as f64 / wall_s
        ));
        levels.push(FleetLevel {
            parallel: budget.runs_parallel,
            kernel_threads: budget.kernel_threads,
            wall_s,
            runs_per_s: cfg.n_runs as f64 / wall_s,
            speedup_vs_p1: base_wall / wall_s,
            mean_acc: acc.summary().mean,
            bit_identical_to_p1: bit_identical,
        });
    }
    // Echo the EFFECTIVE protocol (clamped dataset sizes), so regenerating
    // from the recorded file reproduces the measured workload.
    let mut effective = cfg.clone();
    effective.train_n = train_n;
    effective.test_n = test_n;
    Ok(FleetReport {
        tag: cfg
            .tag
            .clone()
            .unwrap_or_else(|| format!("{}_fleet", factory.kind().name())),
        backend_name: factory.kind().name().to_string(),
        variant: cfg.variant.clone(),
        cores,
        config: effective,
        levels,
    })
}

/// Run the full protocol described by `cfg` and return the report (the
/// caller decides whether to [`Report::write`] it).
pub fn run(cfg: &BenchConfig) -> Result<Report> {
    run_observed(cfg, &mut NullObserver)
}

/// [`run`] with an observer: one log line per measured seed, and a
/// cancellation poll between seeds (the job engine's progress feed).
/// Observation is passive — the measured numbers are unchanged.
pub fn run_observed(cfg: &BenchConfig, obs: &mut dyn Observer) -> Result<Report> {
    let mut engine = create_default_backend(cfg.backend, &cfg.variant)?;
    let engine = engine.as_mut();
    let batch = engine.batch_train();
    let hw = engine.variant().image_hw;
    let train_n = cfg.train_n.max(2 * batch);
    let test_n = cfg.test_n.max(engine.batch_eval());
    // Generated at the variant's resolution, so the micro-phase batch copy
    // below can never silently mismatch.
    let synth = |n: usize| SynthConfig { n, hw, ..SynthConfig::default() };
    let train_ds = cifar_like(&synth(train_n), 0xBE9C, 0);
    let test_ds = cifar_like(&synth(test_n), 0xBE9C, 1);
    let whiten_samples = train_n.min(1024);

    let base_cfg = TrainConfig {
        variant: cfg.variant.to_string(),
        epochs: cfg.epochs,
        workers: cfg.workers,
        whiten_samples,
        eval_every_epoch: false,
        ..TrainConfig::default()
    };

    // §3.7: pay every one-time cost before the clock starts.
    for _ in 0..cfg.warmup_runs {
        warmup(engine, &train_ds, &base_cfg)?;
    }

    let mut report = Report {
        tag: cfg
            .tag
            .clone()
            .unwrap_or_else(|| format!("{}_{}", engine.name(), cfg.variant)),
        backend_name: engine.name().to_string(),
        variant: cfg.variant.clone(),
        batch_train: batch,
        config: cfg.clone(),
        // The engine reports the thread count its kernels actually use —
        // not the process default, which a builder override may differ from.
        threads: engine.kernel_threads(),
        kernel: engine.kernel_name().to_string(),
        cpu_features: crate::runtime::native::simd::cpu_features()
            .iter()
            .map(|s| s.to_string())
            .collect(),
        step_ms: Dist::default(),
        init_ms: Dist::default(),
        eval_ms: Dist::default(),
        run_s: Dist::default(),
        run_train_s: Dist::default(),
        run_eval_s: Dist::default(),
        run_acc: Dist::default(),
        flops_per_step: engine.variant().train_flops_per_example() as f64 * batch as f64,
        stats: *engine.stats(),
    };

    // A fixed training batch for the micro phase (augmentation excluded:
    // this phase isolates backend step time; the macro phase covers the
    // full pipeline). copy_from_slice panics loudly on any size mismatch —
    // a degenerate all-zero batch must never be silently timed.
    let mut images = crate::tensor::Tensor::zeros(&[batch, 3, hw, hw]);
    for i in 0..batch {
        images
            .image_mut(i)
            .copy_from_slice(train_ds.images.image(i % train_ds.len()));
    }
    let labels: Vec<i32> = (0..batch)
        .map(|i| train_ds.labels[i % train_ds.len()] as i32)
        .collect();

    for run in 0..cfg.runs {
        if obs.cancelled() {
            return Err(Cancelled.into());
        }
        let seed = run as u64;
        // ---- micro: init phase (state init + whitening stats) ----------
        let t0 = Instant::now();
        let mut state = engine.init_state(&InitConfig { dirac: true, seed });
        let head = train_ds.head(whiten_samples);
        let wk = engine.variant().hyper.whiten_kernel;
        state.set_whitening(crate::whitening::whitening_weights(
            &head.images,
            wk,
            base_cfg.whiten_eps,
        )?)?;
        report.init_ms.push(t0.elapsed().as_secs_f64() * 1e3);

        // ---- micro: per-step medians ------------------------------------
        let mut samples = Vec::with_capacity(cfg.steps);
        for _ in 0..cfg.steps {
            let t0 = Instant::now();
            engine.train_step(&mut state, &images, &labels, 1e-3, 0.1, true)?;
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        // Same median definition as the cross-run Dist reporting (even
        // counts average the two middle samples).
        report.step_ms.push(Dist { per_run: samples }.median());

        // ---- micro: one full TTA evaluation -----------------------------
        let t0 = Instant::now();
        let _ = evaluate(engine, &state, &test_ds, base_cfg.tta)?;
        report.eval_ms.push(t0.elapsed().as_secs_f64() * 1e3);

        // ---- macro: one paper-protocol run ------------------------------
        let run_cfg = TrainConfig { seed, ..base_cfg.clone() };
        let (result, _state) = train_full(engine, &train_ds, &test_ds, &run_cfg)?;
        let PhaseTimes { setup_seconds: _, train_seconds, eval_seconds } = result.phases;
        report.run_s.push(result.time_seconds);
        report.run_train_s.push(train_seconds);
        report.run_eval_s.push(eval_seconds);
        report.run_acc.push(result.accuracy);
        obs.on_log(&format!(
            "[bench] seed {run}: run {:.2}s, step median {:.2}ms",
            result.time_seconds,
            report.step_ms.per_run.last().copied().unwrap_or(0.0)
        ));
    }
    report.stats = *engine.stats();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist_median_and_summary() {
        let d = Dist { per_run: vec![3.0, 1.0, 2.0] };
        assert_eq!(d.median(), 2.0);
        let e = Dist { per_run: vec![4.0, 1.0, 2.0, 3.0] };
        assert_eq!(e.median(), 2.5);
        assert_eq!(Dist::default().median(), 0.0);
        assert_eq!(d.summary().n, 3);
    }

    #[test]
    fn validate_rejects_broken_documents() {
        use crate::util::json::parse;
        // A minimal valid skeleton is exercised end-to-end by
        // tests/bench_harness.rs; here: the validator must fail loudly on
        // structural damage.
        assert!(validate(&parse("{}").unwrap()).is_err());
        assert!(validate(&parse(r#"{"schema": "nope"}"#).unwrap()).is_err());
    }

    /// The smallest document [`validate`] accepts, with the fields the
    /// ISA-comparability guard dispatches on left substitutable.
    fn minimal_doc(schema: &str, arch: &str, kernel_field: &str) -> crate::util::json::Json {
        let phase = r#"{"n": 1, "mean": 1.0, "std": 0.0, "min": 1.0, "max": 1.0, "median": 1.0, "per_run": [1.0]}"#;
        let s = format!(
            r#"{{
              "schema": "{schema}", "tag": "t", "backend": "native", "variant": "nano",
              "created_unix": 0,
              "protocol": {{"warmup_runs": 1, "runs": 1, "seeds": [0], "steps_per_run": 1,
                            "epochs": 1.0, "train_n": 1, "test_n": 1, "batch_train": 1}},
              "env": {{"threads": 1, "workers": 0, "os": "linux", "arch": "{arch}"{kernel_field}}},
              "phases": {{"train_step_ms": {phase}, "init_ms": {phase}, "eval_ms": {phase},
                          "run_s": {phase}, "run_train_s": {phase}, "run_eval_s": {phase},
                          "run_acc": {phase}}},
              "derived": {{"flops_per_step": 1.0, "train_gflops": 1.0}},
              "backend_stats": {{"train_steps": 1, "train_exec_secs": 1.0, "compile_secs": 0.0}}
            }}"#
        );
        crate::util::json::parse(&s).unwrap()
    }

    const V2_KERNEL: &str = r#", "kernel": "scalar_4x8", "cpu_features": ["sse2"]"#;

    #[test]
    fn validate_accepts_both_schema_versions() {
        validate(&minimal_doc(SCHEMA, "x86_64", V2_KERNEL)).unwrap();
        // v1 (no kernel fields) must stay checkable — committed baselines.
        validate(&minimal_doc(SCHEMA_V1, "x86_64", "")).unwrap();
        // v2 without the kernel record is invalid.
        assert!(validate(&minimal_doc(SCHEMA, "x86_64", "")).is_err());
    }

    #[test]
    fn comparable_refuses_cross_isa_and_cross_kernel() {
        let base = minimal_doc(SCHEMA, "x86_64", V2_KERNEL);
        comparable(&base, &base).unwrap();
        // Different arch: not comparable even with equal kernels.
        let arm = minimal_doc(SCHEMA, "aarch64", V2_KERNEL);
        let e = comparable(&base, &arm).unwrap_err();
        assert!(format!("{e:#}").contains("env.arch"), "{e:#}");
        // Same arch, different register tile.
        let avx = minimal_doc(SCHEMA, "x86_64", r#", "kernel": "avx2_6x16", "cpu_features": ["avx2"]"#);
        let e = comparable(&base, &avx).unwrap_err();
        assert!(format!("{e:#}").contains("env.kernel"), "{e:#}");
        // v1 partner: kernel unknown, refuse rather than guess.
        let v1 = minimal_doc(SCHEMA_V1, "x86_64", "");
        let e = comparable(&base, &v1).unwrap_err();
        assert!(format!("{e:#}").contains("schema v2"), "{e:#}");
    }
}
