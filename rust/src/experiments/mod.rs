//! Shared experiment harness used by every bench target and example.
//!
//! [`Lab`] owns the PJRT client, the manifest, compiled engines (cached per
//! variant — compile once, train many, §3.7), and the datasets (real
//! CIFAR-10 binaries when present, synthetic class-structured data
//! otherwise — DESIGN.md §3). [`Scale`] centralizes the testbed scaling
//! knobs (runs per cell, dataset sizes, epoch budgets) so every bench is
//! consistent and CI-friendly; override via environment:
//!
//! ```text
//! AIRBENCH_RUNS=20 AIRBENCH_TRAIN_N=4096 cargo bench --bench table1_distribution
//! ```

use std::collections::BTreeMap;

use anyhow::Result;
use xla::PjRtClient;

use crate::config::TrainConfig;
use crate::coordinator::fleet::{run_fleet, FleetResult};
use crate::data::{cifar_bin, synthetic, Dataset};
use crate::runtime::{cpu_client, Engine, Manifest};

/// Testbed scaling knobs (paper-scale values in comments).
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    /// Runs per experiment cell (paper: 400 for Table 2/6, 10k for Table 4).
    pub runs: usize,
    /// Training-set size (paper: 50,000).
    pub n_train: usize,
    /// Test-set size (paper: 10,000).
    pub n_test: usize,
    /// Baseline epoch budget (paper airbench94: 9.9).
    pub epochs: f64,
}

impl Default for Scale {
    fn default() -> Self {
        Scale {
            runs: 8,
            n_train: 256,
            n_test: 512,
            epochs: 8.0,
        }
    }
}

impl Scale {
    /// Read overrides from `AIRBENCH_RUNS`, `AIRBENCH_TRAIN_N`,
    /// `AIRBENCH_TEST_N`, `AIRBENCH_EPOCHS`.
    pub fn from_env() -> Scale {
        fn env<T: std::str::FromStr>(key: &str, default: T) -> T {
            std::env::var(key)
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(default)
        }
        let d = Scale::default();
        Scale {
            runs: env("AIRBENCH_RUNS", d.runs),
            n_train: env("AIRBENCH_TRAIN_N", d.n_train),
            n_test: env("AIRBENCH_TEST_N", d.n_test),
            epochs: env("AIRBENCH_EPOCHS", d.epochs),
        }
    }
}

/// Which dataset distribution an experiment trains on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataKind {
    /// Real CIFAR-10 if the binaries exist, else the CIFAR-like generator.
    Cifar10,
    Cifar100Like,
    ImagenetLike,
    SvhnLike,
    CinicLike,
}

/// The experiment laboratory: client + engines + datasets.
pub struct Lab {
    pub manifest: Manifest,
    pub client: PjRtClient,
    pub scale: Scale,
    engines: BTreeMap<String, Engine>,
    datasets: BTreeMap<String, (Dataset, Dataset)>,
}

impl Lab {
    pub fn new() -> Result<Lab> {
        Ok(Lab {
            manifest: Manifest::load(&Manifest::default_dir())?,
            client: cpu_client()?,
            scale: Scale::from_env(),
            engines: BTreeMap::new(),
            datasets: BTreeMap::new(),
        })
    }

    /// Compiled engine for `variant` (cached).
    pub fn engine(&mut self, variant: &str) -> Result<&mut Engine> {
        if !self.engines.contains_key(variant) {
            let e = Engine::load(&self.client, &self.manifest, variant)?;
            self.engines.insert(variant.to_string(), e);
        }
        Ok(self.engines.get_mut(variant).unwrap())
    }

    /// (train, test) datasets for `kind` at the lab's scale (cached).
    pub fn data(&mut self, kind: DataKind) -> (Dataset, Dataset) {
        let key = format!("{kind:?}-{}-{}", self.scale.n_train, self.scale.n_test);
        if let Some(pair) = self.datasets.get(&key) {
            return pair.clone();
        }
        let (n, m) = (self.scale.n_train, self.scale.n_test);
        let pair = match kind {
            DataKind::Cifar10 => {
                if let (Some(tr), Some(te)) = (
                    cifar_bin::try_real_cifar10(true),
                    cifar_bin::try_real_cifar10(false),
                ) {
                    (tr.head(n), te.head(m))
                } else {
                    let cfg = synthetic::SynthConfig::default();
                    (
                        synthetic::cifar_like(&cfg.clone().with_n(n), 0xC1FA, 0),
                        synthetic::cifar_like(&cfg.with_n(m), 0xC1FA, 1),
                    )
                }
            }
            DataKind::Cifar100Like => (
                synthetic::cifar100_like(n, 0xC100, 0),
                synthetic::cifar100_like(m, 0xC100, 1),
            ),
            DataKind::ImagenetLike => (
                synthetic::imagenet_like(n, 0x1A6E, 0),
                synthetic::imagenet_like(m, 0x1A6E, 1),
            ),
            DataKind::SvhnLike => (
                synthetic::svhn_like(n, 0x54A8, 0),
                synthetic::svhn_like(m, 0x54A8, 1),
            ),
            DataKind::CinicLike => (
                synthetic::cinic_like(n, 0xC121, 0),
                synthetic::cinic_like(m, 0xC121, 1),
            ),
        };
        self.datasets.insert(key, pair.clone());
        pair
    }

    /// Run a fleet of `runs` trainings of `cfg` on `kind` data.
    pub fn fleet(&mut self, kind: DataKind, cfg: &TrainConfig, runs: usize) -> Result<FleetResult> {
        let (train, test) = self.data(kind);
        let engine = self.engine(&cfg.variant)?;
        run_fleet(engine, &train, &test, cfg, runs, None)
    }

    /// Base config at the lab's scale (bench variant, lab epochs).
    pub fn base_config(&self) -> TrainConfig {
        TrainConfig {
            epochs: self.scale.epochs,
            ..TrainConfig::default()
        }
    }
}

/// Format an accuracy as the paper prints them (`94.01%`).
pub fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

/// Format a ± CI half width.
pub fn pct_ci(mean: f64, ci: f64) -> String {
    format!("{:.2}±{:.2}%", 100.0 * mean, 100.0 * ci)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_env_parsing() {
        // Only checks the default path (env mutation is process-global and
        // racy under the parallel test harness).
        let s = Scale::from_env();
        assert!(s.runs >= 1);
        assert!(s.n_train >= 1);
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.9401), "94.01%");
        assert_eq!(pct_ci(0.94, 0.0014), "94.00±0.14%");
    }
}
