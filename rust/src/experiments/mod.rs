//! Shared experiment harness used by every bench target and example.
//!
//! [`Lab`] owns backends (cached per variant — compile once, train many,
//! §3.7) and the datasets (real CIFAR-10 binaries when present, synthetic
//! class-structured data otherwise — DESIGN.md §3). The execution backend
//! is selected per DESIGN.md §2: `auto` resolves to PJRT when the AOT
//! artifacts and a real PJRT runtime exist, else to the pure-Rust native
//! backend — so every bench and example runs on every machine. Force a
//! backend with `AIRBENCH_BACKEND=native|pjrt` (or [`Lab::with_backend`]).
//!
//! [`Scale`] centralizes the testbed scaling knobs (runs per cell, dataset
//! sizes, epoch budgets) so every bench is consistent and CI-friendly;
//! override via environment:
//!
//! ```text
//! AIRBENCH_RUNS=20 AIRBENCH_TRAIN_N=4096 cargo bench --bench table1_distribution
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::Result;
use xla::PjRtClient;

use crate::config::TrainConfig;
use crate::coordinator::fleet::{run_fleet, FleetResult};
use crate::data::{cifar_bin, synthetic, Dataset};
use crate::runtime::{cpu_client, Backend, BackendKind, Manifest, NativeBackend, PjrtBackend};

/// Testbed scaling knobs (paper-scale values in comments).
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    /// Runs per experiment cell (paper: 400 for Table 2/6, 10k for Table 4).
    pub runs: usize,
    /// Training-set size (paper: 50,000).
    pub n_train: usize,
    /// Test-set size (paper: 10,000).
    pub n_test: usize,
    /// Baseline epoch budget (paper airbench94: 9.9).
    pub epochs: f64,
}

impl Default for Scale {
    fn default() -> Self {
        Scale {
            runs: 8,
            n_train: 256,
            n_test: 512,
            epochs: 8.0,
        }
    }
}

impl Scale {
    /// Read overrides from `AIRBENCH_RUNS`, `AIRBENCH_TRAIN_N`,
    /// `AIRBENCH_TEST_N`, `AIRBENCH_EPOCHS`.
    pub fn from_env() -> Scale {
        fn env<T: std::str::FromStr>(key: &str, default: T) -> T {
            std::env::var(key)
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(default)
        }
        let d = Scale::default();
        Scale {
            runs: env("AIRBENCH_RUNS", d.runs),
            n_train: env("AIRBENCH_TRAIN_N", d.n_train),
            n_test: env("AIRBENCH_TEST_N", d.n_test),
            epochs: env("AIRBENCH_EPOCHS", d.epochs),
        }
    }
}

/// Which dataset distribution an experiment trains on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataKind {
    /// Real CIFAR-10 if the binaries exist, else the CIFAR-like generator.
    Cifar10,
    /// CIFAR-100-difficulty synthetic distribution (Table 5).
    Cifar100Like,
    /// ImageNet-style 48px synthetic distribution (Table 3, §5.2 crops).
    ImagenetLike,
    /// SVHN-like chirality distribution where flipping hurts (Table 5).
    SvhnLike,
    /// CINIC-10-like noisier CIFAR distribution (Table 5).
    CinicLike,
}

impl DataKind {
    /// Parse a CLI / job spelling
    /// (`cifar10|cifar100|imagenet|svhn|cinic`).
    pub fn parse(s: &str) -> Option<DataKind> {
        match s {
            "cifar10" => Some(DataKind::Cifar10),
            "cifar100" => Some(DataKind::Cifar100Like),
            "imagenet" => Some(DataKind::ImagenetLike),
            "svhn" => Some(DataKind::SvhnLike),
            "cinic" => Some(DataKind::CinicLike),
            _ => None,
        }
    }

    /// Canonical spelling (inverse of [`DataKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            DataKind::Cifar10 => "cifar10",
            DataKind::Cifar100Like => "cifar100",
            DataKind::ImagenetLike => "imagenet",
            DataKind::SvhnLike => "svhn",
            DataKind::CinicLike => "cinic",
        }
    }
}

/// Build the `(train, test)` datasets for `kind` at sizes `(n, m)` — the
/// one dataset constructor [`Lab::data`] and the `api` job engine share,
/// so a job submitted over the API trains on exactly the data the CLI
/// would have used (real CIFAR-10 binaries when present on disk,
/// deterministic synthetic distributions otherwise).
pub fn make_data(kind: DataKind, n: usize, m: usize) -> (Dataset, Dataset) {
    match kind {
        DataKind::Cifar10 => {
            if let (Some(tr), Some(te)) = (
                cifar_bin::try_real_cifar10(true),
                cifar_bin::try_real_cifar10(false),
            ) {
                (tr.head(n), te.head(m))
            } else {
                let cfg = synthetic::SynthConfig::default();
                (
                    synthetic::cifar_like(&cfg.clone().with_n(n), 0xC1FA, 0),
                    synthetic::cifar_like(&cfg.with_n(m), 0xC1FA, 1),
                )
            }
        }
        DataKind::Cifar100Like => (
            synthetic::cifar100_like(n, 0xC100, 0),
            synthetic::cifar100_like(m, 0xC100, 1),
        ),
        DataKind::ImagenetLike => (
            synthetic::imagenet_like(n, 0x1A6E, 0),
            synthetic::imagenet_like(m, 0x1A6E, 1),
        ),
        DataKind::SvhnLike => (
            synthetic::svhn_like(n, 0x54A8, 0),
            synthetic::svhn_like(m, 0x54A8, 1),
        ),
        DataKind::CinicLike => (
            synthetic::cinic_like(n, 0xC121, 0),
            synthetic::cinic_like(m, 0xC121, 1),
        ),
    }
}

/// The experiment laboratory: backends + datasets behind one handle.
pub struct Lab {
    /// Experiment scale knobs (`AIRBENCH_RUNS` / `AIRBENCH_TRAIN_N` /
    /// `AIRBENCH_TEST_N` / `AIRBENCH_EPOCHS` overrides).
    pub scale: Scale,
    kind: BackendKind,
    artifacts_dir: PathBuf,
    /// Lazily created, PJRT path only.
    manifest: Option<Manifest>,
    client: Option<PjRtClient>,
    backends: BTreeMap<String, Box<dyn Backend>>,
    datasets: BTreeMap<String, (Dataset, Dataset)>,
}

impl Lab {
    /// Backend kind from `AIRBENCH_BACKEND` (default `auto`). An
    /// unparseable value is a loud error, not a silent `auto`.
    pub fn new() -> Result<Lab> {
        let kind = match std::env::var("AIRBENCH_BACKEND") {
            Ok(v) => BackendKind::parse(&v).ok_or_else(|| {
                anyhow::anyhow!("AIRBENCH_BACKEND='{v}' is not auto|pjrt|native")
            })?,
            Err(_) => BackendKind::Auto,
        };
        Lab::with_backend(kind)
    }

    /// Build a lab with an explicit backend kind (tests / benches).
    pub fn with_backend(kind: BackendKind) -> Result<Lab> {
        Ok(Lab {
            scale: Scale::from_env(),
            kind,
            artifacts_dir: Manifest::default_dir(),
            manifest: None,
            client: None,
            backends: BTreeMap::new(),
            datasets: BTreeMap::new(),
        })
    }

    /// Where AOT artifacts are looked up (`AIRBENCH_ARTIFACTS` override).
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Override the backend kind (takes effect for backends not yet
    /// created; the CLI calls this after parsing `--backend`).
    pub fn set_backend(&mut self, kind: BackendKind) {
        self.kind = kind;
    }

    /// The configured (possibly still `Auto`) kind, without resolving it —
    /// what an [`crate::runtime::EngineSpec`] wants, since the factory does
    /// its own `Auto` resolution.
    pub fn kind(&self) -> BackendKind {
        self.kind
    }

    /// The kind this lab executes with, resolving `auto` by attempting the
    /// PJRT path once — the manifest and client built by a successful
    /// attempt are kept (not a throwaway probe), so backends reuse them.
    pub fn backend_kind(&mut self) -> BackendKind {
        if self.kind == BackendKind::Auto {
            self.kind = match self.init_pjrt() {
                Ok(()) => BackendKind::Pjrt,
                Err(_) => BackendKind::Native,
            };
        }
        self.kind
    }

    /// Load the manifest + create the PJRT client (idempotent).
    fn init_pjrt(&mut self) -> Result<()> {
        if self.manifest.is_none() {
            self.manifest = Some(Manifest::load(&self.artifacts_dir)?);
        }
        if self.client.is_none() {
            self.client = Some(cpu_client()?);
        }
        Ok(())
    }

    /// Loaded backend for `variant` (cached — compile once, train many).
    pub fn backend(&mut self, variant: &str) -> Result<&mut dyn Backend> {
        if !self.backends.contains_key(variant) {
            let b = self.create(variant)?;
            self.backends.insert(variant.to_string(), b);
        }
        Ok(self.backends.get_mut(variant).unwrap().as_mut())
    }

    fn create(&mut self, variant: &str) -> Result<Box<dyn Backend>> {
        match self.backend_kind() {
            BackendKind::Native => Ok(Box::new(NativeBackend::new(
                variant,
                &self.artifacts_dir,
            )?)),
            _ => {
                self.init_pjrt()?;
                Ok(Box::new(PjrtBackend::load(
                    self.client.as_ref().unwrap(),
                    self.manifest.as_ref().unwrap(),
                    variant,
                )?))
            }
        }
    }

    /// (train, test) datasets for `kind` at the lab's scale (cached).
    pub fn data(&mut self, kind: DataKind) -> (Dataset, Dataset) {
        let key = format!("{kind:?}-{}-{}", self.scale.n_train, self.scale.n_test);
        if let Some(pair) = self.datasets.get(&key) {
            return pair.clone();
        }
        let pair = make_data(kind, self.scale.n_train, self.scale.n_test);
        self.datasets.insert(key, pair.clone());
        pair
    }

    /// Run a fleet of `runs` trainings of `cfg` on `kind` data.
    pub fn fleet(&mut self, kind: DataKind, cfg: &TrainConfig, runs: usize) -> Result<FleetResult> {
        let (train, test) = self.data(kind);
        let engine = self.backend(&cfg.variant)?;
        run_fleet(engine, &train, &test, cfg, runs, None)
    }

    /// Base config at the lab's scale (bench variant, lab epochs).
    pub fn base_config(&self) -> TrainConfig {
        TrainConfig {
            epochs: self.scale.epochs,
            ..TrainConfig::default()
        }
    }
}

/// Format an accuracy as the paper prints them (`94.01%`).
pub fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

/// Format a ± CI half width.
pub fn pct_ci(mean: f64, ci: f64) -> String {
    format!("{:.2}±{:.2}%", 100.0 * mean, 100.0 * ci)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_env_parsing() {
        // Only checks the default path (env mutation is process-global and
        // racy under the parallel test harness).
        let s = Scale::from_env();
        assert!(s.runs >= 1);
        assert!(s.n_train >= 1);
    }

    #[test]
    fn data_kind_spellings_round_trip() {
        for kind in [
            DataKind::Cifar10,
            DataKind::Cifar100Like,
            DataKind::ImagenetLike,
            DataKind::SvhnLike,
            DataKind::CinicLike,
        ] {
            assert_eq!(DataKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(DataKind::parse("mnist"), None);
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.9401), "94.01%");
        assert_eq!(pct_ci(0.94, 0.0014), "94.00±0.14%");
    }

    #[test]
    fn lab_always_provides_a_backend() {
        // `auto` must resolve to SOMETHING on every machine — that is the
        // point of the backend seam.
        let mut lab = Lab::new().unwrap();
        let kind = lab.backend_kind();
        assert_ne!(kind, BackendKind::Auto, "auto must resolve");
        let b = lab.backend("bench").unwrap();
        assert_eq!(b.variant().name, "bench");
        // cached: second call returns the same loaded backend
        let steps_before = lab.backend("bench").unwrap().stats().train_steps;
        assert_eq!(steps_before, 0);
    }

    #[test]
    fn forced_native_lab_works_without_artifacts() {
        let mut lab = Lab::with_backend(BackendKind::Native).unwrap();
        assert_eq!(lab.backend_kind(), BackendKind::Native);
        let b = lab.backend("nano").unwrap();
        assert_eq!(b.name(), "native");
        assert_eq!(b.batch_train(), 8);
    }
}
